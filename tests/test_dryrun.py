"""Dry-run machinery: cell specs build for every arch x shape (abstractly),
collective parsing works on known HLO, and one real 512-device lower+compile
runs in a subprocess (the full 64-cell sweep lives in experiments/dryrun)."""

from __future__ import annotations

import os
import subprocess
import sys

import pytest

from repro.launch.roofline import (analytic_bytes, parse_collectives,
                                   roofline_terms)
from repro.configs.base import SHAPES, get_arch, shapes_for
from repro.configs import archs


def test_parse_collectives_known_text():
    hlo = """
  %ag = f32[512,1024]{1,0} all-gather(f32[32,1024]{1,0} %p), dimensions={0}
  %ar = bf16[128]{0} all-reduce(bf16[128]{0} %x), to_apply=%sum
  %rs = f32[4,8]{1,0} reduce-scatter(f32[64,8]{1,0} %y), dimensions={0}
  %cp = f32[16]{0} collective-permute(f32[16]{0} %z)
  %done = f32[512,1024]{1,0} all-gather-done(f32[512,1024]{1,0} %ag)
  %plain = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""
    out = parse_collectives(hlo)
    # Spec-defined counting: SUM OF OPERAND SIZES.  Here operand shapes are
    # printed inline, so they are used directly (result shapes ignored).
    assert out["all-gather"]["bytes"] == 32 * 1024 * 4
    assert out["all-gather"]["count"] == 1          # -done not recounted
    assert out["all-reduce"]["bytes"] == 128 * 2
    assert out["reduce-scatter"]["bytes"] == 64 * 8 * 4
    assert out["collective-permute"]["bytes"] == 16 * 4


def test_parse_collectives_derives_from_result():
    """When XLA omits inline operand shapes (the CPU backend's format),
    operand bytes derive from the result type + collective semantics."""
    hlo = """
  %ag = f32[3584,512]{0,1} all-gather(%fusion.1), channel_id=1, replica_groups=[16,16]<=[256], dimensions={1}
  %ar = f32[128]{0} all-reduce(%x), channel_id=2, replica_groups=[16,16]<=[256], to_apply=%sum
  %rs = f32[4,8]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[8,4]<=[32], dimensions={0}
  %a2a = (f32[2,8]{1,0}, f32[2,8]{1,0}) all-to-all(%p, %q), channel_id=4, replica_groups=[16,2]<=[32]
  %cp = f32[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %agold = f32[64]{0} all-gather(%w), replica_groups={{0,1,2,3}}, dimensions={0}
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["bytes"] == 3584 * 512 * 4 / 16 + 64 * 4 / 4
    assert out["all-reduce"]["bytes"] == 128 * 4
    assert out["reduce-scatter"]["bytes"] == 4 * 8 * 4 * 4
    assert out["all-to-all"]["bytes"] == 2 * 2 * 8 * 4
    assert out["collective-permute"]["bytes"] == 16 * 4


def test_roofline_terms_math():
    rf = roofline_terms(flops_per_device=197e12, bytes_per_device=819e9,
                        coll_bytes_per_device=50e9, chips=256,
                        model_flops=197e12 * 256 / 2)
    assert rf["t_compute_s"] == pytest.approx(1.0)
    assert rf["t_memory_s"] == pytest.approx(1.0)
    assert rf["t_collective_s"] == pytest.approx(1.0)
    assert rf["useful_flops_ratio"] == pytest.approx(0.5)
    assert rf["roofline_fraction"] == pytest.approx(0.5)


def test_analytic_bytes_sane():
    """Analytic memory model: decode reads ~active params + cache."""
    cfg = get_arch("qwen2-7b")
    by = analytic_bytes(cfg, SHAPES["decode_32k"], 256)
    p_bytes = cfg.param_count() * 2 / 256
    assert by > p_bytes                      # params plus cache
    assert by < p_bytes * 20                 # but not absurd
    tr = analytic_bytes(cfg, SHAPES["train_4k"], 256)
    assert tr > by                           # training moves far more


def test_model_flops_6nd():
    from repro.launch.specs import model_flops
    cfg = get_arch("llama3.2-3b")
    sh = SHAPES["train_4k"]
    want = 6 * cfg.param_count() * sh.global_batch * sh.seq_len
    assert model_flops(cfg, sh) == pytest.approx(want, rel=1e-6)
    moe = get_arch("qwen3-moe-235b-a22b")
    assert moe.active_param_count() < 0.2 * moe.param_count()
    assert model_flops(moe, sh) == pytest.approx(
        6 * moe.active_param_count() * sh.global_batch * sh.seq_len,
        rel=1e-6)


def test_one_real_cell_compiles_on_512_devices():
    """Subprocess (device count must not leak into this pytest process)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "llama3.2-3b", "--shape", "decode_32k",
         "--mesh", "multi", "--out", ""],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "[OK] llama3.2-3b x decode_32k x 2x16x16" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-2000:]


def test_all_cells_have_dryrun_artifacts():
    """The committed sweep results cover all 64 compile-proof cells."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun")
    if not os.path.isdir(d):
        pytest.skip("sweep artifacts not present")
    import json
    n_ok = 0
    for a in archs.ALL:
        for s in shapes_for(get_arch(a)):
            for pod in ("single", "multi"):
                p = os.path.join(d, f"{a}_{s}_{pod}.json")
                assert os.path.exists(p), f"missing {p}"
                with open(p) as f:
                    assert json.load(f)["ok"], f"cell failed: {p}"
                n_ok += 1
    assert n_ok == 64


_SCALED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.dryrun import _lower_stats
from repro.configs.base import get_arch

# differential-depth: predict depth-4 stats from depths 1 and 2, compare
# against the actual depth-4 unrolled lower (llama: period length 1).
s1 = _lower_stats("llama3.2-3b", "prefill_32k", False, 1)
s2 = _lower_stats("llama3.2-3b", "prefill_32k", False, 2)
s4 = _lower_stats("llama3.2-3b", "prefill_32k", False, 4)

for key, tol in (("flops", 0.02), ("coll_bytes", 0.05)):
    pred = s1[key] + (s2[key] - s1[key]) * 3
    actual = s4[key]
    if actual == 0:
        assert pred == 0, (key, pred)
        continue
    rel = abs(pred - actual) / actual
    assert rel < tol, (key, pred, actual, rel)
print("SCALED_OK")
"""


def test_scaled_matches_unrolled():
    """The differential-depth roofline extrapolation (§Dry-run caveats)
    matches a deeper full unroll on a real arch (subprocess, 512 dev)."""
    import os as _os
    env = dict(_os.environ)
    env["PYTHONPATH"] = _os.path.abspath(
        _os.path.join(_os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCALED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert "SCALED_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-2500:]


def test_optimized_variant_compiles_multi_pod():
    """The beyond-paper layout (attn_shard=seq + causal_bound) must also
    pass the production multi-pod dry-run (2x16x16), not just single-pod."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.dryrun import run_cell\n"
        "rec = run_cell('qwen2-7b', 'prefill_32k', True, '', overrides="
        "{'attn_shard': 'seq', 'causal_bound': True, "
        "'n_layers': 2, 'static_unroll': True})\n"
        "assert rec['ok'], rec.get('error')\n"
        "assert rec['roofline']['t_collective_s'] < 0.1, rec['roofline']\n"
        "print('OPT_MULTIPOD_OK')\n")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "OPT_MULTIPOD_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-2500:]
