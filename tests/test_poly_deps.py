"""Paper Appendix A: the ISL-computed ``S`` relation ≡ brute force.

For random (writer, reader) access-relation pairs drawn from the operator
families the paper targets (conv windows per Listing 2, pooling, pointwise,
full reads), we check that the generated-code LCU frontier (``poly.Frontier``)
matches an exhaustively enumerated dependency oracle at *every* prefix of the
write stream.
"""

from __future__ import annotations

from typing import Dict, List, Set, Tuple

import pytest
pytest.importorskip("hypothesis")  # gated: optional test dep
from hypothesis import given, settings, strategies as st

from repro.core import poly
from repro.core.lowering import (WriteSpec, conv_read_relation,
                                 pointwise_read_relation, pool_read_relation,
                                 full_read_relation)

Point = Tuple[int, ...]


# ----------------------------------------------------------------- brute force
def brute_frontier_trace(writes: List[Tuple[Point, List[Point]]],
                         reader_space: List[Point],
                         read_deps: Dict[Point, Set[Point]],
                         ever_written: Set[Point]) -> List[Set[Point]]:
    """After each write step, the exact set of safe reader iterations.

    ``read_deps[j]`` = locations j reads *that are ever written* (paper: reads
    of never-written locations, e.g. padding, carry no dependency).
    A reader iteration j is safe iff every iteration zeta <= j has all its
    dependencies satisfied (execution is in lexicographic order, so j can only
    run after all zeta <= j ran).
    """
    seen: Set[Point] = set()
    out: List[Set[Point]] = []
    for _, locs in writes:
        seen.update(locs)
        safe: Set[Point] = set()
        ok_so_far = True
        for j in reader_space:  # lex order
            if not ok_so_far:
                break
            if read_deps[j] <= seen:
                safe.add(j)
            else:
                ok_so_far = False
        out.append(safe)
    return out


def relation_pairs(m) -> List[Tuple[Point, Point]]:
    return poly.enumerate_map(m)


def run_case(W1, R2, writer_space: List[Point]) -> None:
    """Drive Frontier with the write stream; compare to brute force."""
    dep = poly.compute_dep_info(W1, R2)
    src, fn = poly.generate_s_evaluator(dep)
    frontier = poly.Frontier(dep, fn)

    w_pairs = relation_pairs(W1)
    writes_by_iter: Dict[Point, List[Point]] = {}
    for i, o in w_pairs:
        writes_by_iter.setdefault(i, []).append(o)

    r_pairs = relation_pairs(R2)
    reader_space = sorted({j for j, _ in r_pairs})
    ever_written = {o for _, o in w_pairs}
    read_deps: Dict[Point, Set[Point]] = {j: set() for j in reader_space}
    for j, o in r_pairs:
        if o in ever_written:
            read_deps[j].add(o)

    stream = [(i, writes_by_iter.get(i, [])) for i in sorted(writes_by_iter)]
    oracle = brute_frontier_trace(stream, reader_space, read_deps,
                                  ever_written)

    for (it_w, locs), safe_now in zip(stream, oracle):
        for loc in locs:
            frontier.observe(loc)
        for j in reader_space:
            assert frontier.safe(j) == (j in safe_now), (
                f"writer iter {it_w}: frontier.safe({j}) = "
                f"{frontier.safe(j)}, oracle = {j in safe_now}\n{src}")


# ------------------------------------------------------------------ conv cases
@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(3, 8), w=st.integers(3, 8),
    fh=st.integers(1, 3), fw=st.integers(1, 3),
    stride=st.integers(1, 2), pad=st.integers(0, 1),
    c=st.integers(1, 2),
)
def test_conv_reader_vs_brute(h, w, fh, fw, stride, pad, c):
    """Conv consumer (paper Listing 2) fed by a pixel-streaming producer."""
    oh = (h + 2 * pad - fh) // stride + 1
    ow = (w + 2 * pad - fw) // stride + 1
    if oh <= 0 or ow <= 0:
        pytest.skip("degenerate conv")
    W1 = WriteSpec("A", "pixel", (c, h, w)).isl_write("WR")
    R2 = conv_read_relation("RD", (oh, ow), (c, h, w), fh, fw, stride, pad)
    writer_space = poly.enumerate_set(W1.domain())
    run_case(W1, R2, writer_space)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(2, 8), w=st.integers(2, 8),
    k=st.integers(1, 3), stride=st.integers(1, 3), c=st.integers(1, 2),
)
def test_pool_reader_vs_brute(h, w, k, stride, c):
    """Pooling consumer fed by a pixel producer."""
    oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
    if oh <= 0 or ow <= 0:
        pytest.skip("degenerate pool")
    W1 = WriteSpec("A", "pixel", (c, h, w)).isl_write("WR")
    R2 = pool_read_relation("RD", (oh, ow), (c, h, w), k, stride)
    run_case(W1, R2, poly.enumerate_set(W1.domain()))


@settings(max_examples=10, deadline=None)
@given(h=st.integers(2, 6), w=st.integers(2, 6), c=st.integers(1, 2))
def test_pointwise_reader_vs_brute(h, w, c):
    W1 = WriteSpec("A", "pixel", (c, h, w)).isl_write("WR")
    R2 = pointwise_read_relation("RD", (h, w), (c, h, w))
    run_case(W1, R2, poly.enumerate_set(W1.domain()))


@settings(max_examples=10, deadline=None)
@given(h=st.integers(2, 6), w=st.integers(2, 6), c=st.integers(1, 2))
def test_full_reader_vs_brute(h, w, c):
    """GEMM-style consumer: reads the whole producer array (encoder case —
    the frontier must collapse to wait-for-last-write)."""
    W1 = WriteSpec("A", "pixel", (c, h, w)).isl_write("WR")
    R2 = full_read_relation("RD", (c, h, w))
    run_case(W1, R2, poly.enumerate_set(W1.domain()))


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(3, 8), w=st.integers(3, 8),
    k=st.integers(2, 3), stride=st.integers(1, 2), c=st.integers(1, 2),
)
def test_conv_after_pool_producer_vs_brute(h, w, k, stride, c):
    """Conv consumer fed by a *pool*-kind producer (windows finalize late)."""
    ph, pw = (h - k) // stride + 1, (w - k) // stride + 1
    if ph < 3 or pw < 3:
        pytest.skip("too small after pooling")
    W1 = WriteSpec("A", "pool", (c, ph, pw),
                   dict(k=k, stride=stride)).isl_write("WR")
    R2 = conv_read_relation("RD", (ph - 2, pw - 2), (c, ph, pw), 3, 3, 1, 0)
    run_case(W1, R2, poly.enumerate_set(W1.domain()))


# ----------------------------------------------------------- structural checks
def test_s_is_single_valued_and_monotone():
    """S must be single-valued (lexmax) and monotone in write order."""
    W1 = WriteSpec("A", "pixel", (2, 6, 6)).isl_write("WR")
    R2 = conv_read_relation("RD", (4, 4), (2, 6, 6), 3, 3, 1, 0)
    dep = poly.compute_dep_info(W1, R2)
    assert dep.S.is_single_valued()
    _, fn = poly.generate_s_evaluator(dep)
    # Monotone in *write order*: enumerate writer iterations lexicographically
    # and check the frontier never regresses over the locations each writes.
    prev = None
    for it, loc in poly.enumerate_map(W1):  # sorted by writer iteration
        j = fn(*loc)
        if j is None:
            continue
        if prev is not None:
            assert tuple(j) >= prev, (it, loc, j, prev)
        prev = tuple(j)


def test_listing2_shape():
    """The paper's Listing 2 relation: conv 3x3, stride 1, no padding."""
    R2 = conv_read_relation("CONV_MXV", (4, 4), (3, 6, 6), 3, 3, 1, 0)
    # iteration (0,0) reads rows 0..2, cols 0..2 of every channel
    pairs = [(j, o) for j, o in poly.enumerate_map(R2) if j == (0, 0)]
    locs = {o for _, o in pairs}
    assert locs == {(c, i, j) for c in range(3) for i in range(3)
                    for j in range(3)}


def test_generated_code_is_compilable_python():
    W1 = WriteSpec("A", "pixel", (1, 5, 5)).isl_write("WR")
    R2 = conv_read_relation("RD", (3, 3), (1, 5, 5), 3, 3, 1, 0)
    dep = poly.compute_dep_info(W1, R2)
    src, fn = poly.generate_s_evaluator(dep)
    assert "def s_eval(" in src
    compile(src, "<test>", "exec")  # must be valid Python source
    # padding-free 3x3 conv: write (0,4,4)... last write unlocks everything
    assert fn(0, 4, 4) == (2, 2)
