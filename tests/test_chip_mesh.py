"""Multi-chip scale-out (ChipMesh): chip-level partitioner, per-chip
mapping, inter-chip DMA lowering, and the link model in both simulator
engines.

Equivalence contract (ISSUE 3):
  * ``chips=1`` is bit-identical — outputs AND cycle/message/byte/busy/
    high-water accounting — to the single-chip path;
  * a ``chips=2`` resnet-block-chain run matches reference outputs bitwise
    across both engines and the numpy/reference compute planes.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import (ChipMesh, LinkSpec, PartitionError, Simulator,
                        build_lenet_like, build_resnet_block_chain,
                        compile_model, execute_reference, make_chip,
                        make_mesh, partition_chips, partition_graph,
                        serialize_config)


def _stat_tuple(s):
    return (s.cycles, s.messages, s.bytes_sent, dict(s.busy),
            dict(s.first_busy), dict(s.last_busy),
            dict(s.sram_high_water),
            {k: (v.messages, v.bytes, v.busy) for k, v in s.links.items()})


def _images(n, shp, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=shp).astype(np.float32) for _ in range(n)]


# ------------------------------------------------------------- partitioner
def test_partition_chips_prefers_no_cut_when_it_fits():
    g = build_resnet_block_chain(2)            # 4 partitions
    mesh = make_mesh(2, chip=make_chip(4, "banded"))
    assign = partition_chips(partition_graph(g), mesh)
    assert set(assign.values()) == {0}, "fits on chip 0, no cut"


def test_partition_chips_cuts_at_capacity_min_bytes():
    g = build_resnet_block_chain(4)            # 8 partitions
    mesh = make_mesh(2, chip=make_chip(6, "banded"))
    pg = partition_graph(g)
    assign = partition_chips(pg, mesh)
    # contiguous, capacity-respecting, and every cut edge on a mesh link
    order = [assign[p] for p in sorted(assign)]
    assert order == sorted(order), "assignment must be contiguous"
    for c in set(order):
        assert order.count(c) <= 6
    assert set(order) == {0, 1}
    for (s, d) in pg.edges:
        if s == -1:
            continue
        assert mesh.connected(assign[s], assign[d])


def test_partition_chips_capacity_error():
    g = build_resnet_block_chain(4)            # 8 partitions > 2 x 3 cores
    mesh = make_mesh(2, chip=make_chip(3, "banded"))
    with pytest.raises(PartitionError):
        partition_chips(partition_graph(g), mesh)


def test_link_spec_transfer_delay():
    link = LinkSpec(latency=4, width_bytes=64)
    assert link.transfer_delay(16) == 4        # one beat: latency only
    assert link.transfer_delay(64) == 4
    assert link.transfer_delay(65) == 5        # second beat
    assert link.transfer_delay(640) == 13


# ------------------------------------------------- chips=1 bit-identical
def test_chips1_identical_to_single_chip_path():
    """compile_model(..., chips=1) and a 1-chip mesh both reproduce the
    single-chip run bit-for-bit, outputs and all accounting."""
    graph = build_lenet_like()
    chip = make_chip(8, "banded")
    prog = compile_model(graph, chip)
    prog_c1 = compile_model(graph, chip, chips=1)
    mesh1 = make_mesh(1, chip=chip)
    prog_m1 = compile_model(graph, chip, mesh=mesh1)
    assert prog_c1.mesh is None                 # same code path entirely
    assert prog_m1.dma_streams == []
    images = _images(3, (1, 12, 12))
    for engine in ("event", "reference"):
        for sched in ("pipelined", "sequential"):
            o0, s0 = Simulator(prog, chip, engine=engine).run(
                images, schedule=sched)
            o1, s1 = Simulator(prog_c1, chip, engine=engine).run(
                images, schedule=sched)
            om, sm = Simulator(prog_m1, mesh1, engine=engine).run(
                images, schedule=sched)
            for a, b, c in zip(o0, o1, om):
                for v in a:
                    np.testing.assert_array_equal(a[v], b[v])
                    np.testing.assert_array_equal(a[v], c[v])
            assert _stat_tuple(s0) == _stat_tuple(s1)
            assert _stat_tuple(s0) == _stat_tuple(sm)


# ------------------------------------------------- chips=2 resnet chain
@pytest.fixture(scope="module")
def resnet_two_chip():
    graph = build_resnet_block_chain(4)
    chip = make_chip(6, "banded")
    mesh = make_mesh(2, chip=chip)
    prog = compile_model(graph, chip, chips=2)
    wide = make_chip(12, "banded")
    prog_wide = compile_model(graph, wide)
    return graph, chip, mesh, prog, wide, prog_wide


def test_chips2_splits_and_lowers_dma(resnet_two_chip):
    graph, chip, mesh, prog, wide, prog_wide = resnet_two_chip
    chips_used = {prog.chip_of(c) for c in prog.cores}
    assert chips_used == {0, 1}
    assert prog.dma_streams, "cut edges must lower to inter-chip DMA"
    for s in prog.dma_streams:
        assert (s.src_chip, s.dst_chip) in mesh.links
        # the consumer enforces the cut edge with the same compiled
        # frontier-table ramp machinery as intra-chip edges
        lc = prog.cores[s.dst_core].lcu[s.value]
        assert lc.table is not None


def test_chips2_bitwise_outputs_all_engines_planes(resnet_two_chip):
    graph, chip, mesh, prog, wide, prog_wide = resnet_two_chip
    images = _images(3, (4, 8, 8))
    want = [execute_reference(graph, {"x": im}) for im in images]
    stats = {}
    outs = {}
    for engine in ("event", "reference"):
        for plane in ("numpy", "reference"):
            for sched in ("pipelined", "sequential"):
                o, s = Simulator(prog, mesh, engine=engine,
                                 compute_plane=plane).run(
                    images, schedule=sched)
                outs[(engine, plane, sched)] = o
                stats[(engine, plane, sched)] = s
    # single-chip oracle outputs (the scale-out must not change a bit)
    o_wide, _ = Simulator(prog_wide, wide, engine="event").run(images)
    base = outs[("event", "numpy", "pipelined")]
    for got, ref, w in zip(base, want, o_wide):
        for v in got:
            np.testing.assert_allclose(got[v], ref[v], atol=1e-5)
            np.testing.assert_array_equal(got[v], w[v])
    for key, o in outs.items():
        ref_o = outs[("event", "numpy", key[2])]
        for a, b in zip(o, ref_o):
            for v in a:
                np.testing.assert_array_equal(a[v], b[v], err_msg=str(key))
    # accounting identical across engines (per plane and schedule)
    for plane in ("numpy", "reference"):
        for sched in ("pipelined", "sequential"):
            assert _stat_tuple(stats[("event", plane, sched)]) == \
                _stat_tuple(stats[("reference", plane, sched)]), \
                (plane, sched)


def test_chips2_link_accounting_and_latency(resnet_two_chip):
    graph, chip, mesh, prog, wide, prog_wide = resnet_two_chip
    images = _images(2, (4, 8, 8))
    _, s = Simulator(prog, mesh, engine="event").run(images)
    assert (0, 1) in s.links
    ls = s.links[(0, 1)]
    n_dst = len({d.dst_core for d in prog.dma_streams})
    # one message per finalized pixel of the cut value per consumer core
    c, h, w = 4, 8, 8
    assert ls.messages == len(images) * h * w * n_dst
    assert ls.bytes == ls.messages * c * 4
    assert ls.busy == ls.messages  # 16B rows on a 64B link: 1 beat each
    assert 0.0 < s.link_occupancy((0, 1))
    util = s.chip_utilization(mesh)
    assert len(util) == 2 and all(0.0 < u <= 1.0 for u in util)

    # a slower link strictly delays the pipeline, never changes outputs
    slow = dataclasses.replace(mesh, link=LinkSpec(latency=64,
                                                   width_bytes=4))
    prog_slow = compile_model(graph, chip, mesh=slow)
    o_fast, s_fast = Simulator(prog, mesh, engine="event").run(images)
    for engine in ("event", "reference"):
        o_slow, s_slow = Simulator(prog_slow, slow, engine=engine).run(images)
        assert s_slow.cycles > s_fast.cycles
        for a, b in zip(o_fast, o_slow):
            for v in a:
                np.testing.assert_array_equal(a[v], b[v])


def test_serialize_includes_mesh(resnet_two_chip):
    import json
    graph, chip, mesh, prog, wide, prog_wide = resnet_two_chip
    bundle = json.loads(serialize_config(prog))
    assert bundle["mesh"]["n_chips"] == 2
    assert bundle["mesh"]["cores_per_chip"] == 6
    assert bundle["mesh"]["dma_streams"]
    for s in bundle["mesh"]["dma_streams"]:
        assert s["src_chip"] != s["dst_chip"]


def test_mesh_missing_link_raises():
    """An edge landing on a non-linked chip pair must fail loudly."""
    g = build_resnet_block_chain(4)
    chip = make_chip(6, "banded")
    base = make_mesh(2, chip=chip)
    nolink = ChipMesh(chip=chip, n_chips=2, links=frozenset(),
                      link=base.link)
    with pytest.raises(PartitionError):
        partition_chips(partition_graph(g), nolink)
