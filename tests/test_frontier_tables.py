"""Compiled frontier tables (the vectorized LCU) ≡ brute-force dependency
oracle, on whichever polyhedral backend is active.

Unlike ``test_poly_deps`` (hypothesis-driven, needs islpy semantics),
these cases are deterministic and run on both the islpy backend and the
finite-relation ``fisl`` fallback, covering every operator family the
lowering emits: conv windows (strided / padded), pooling, pointwise, full
reads, and pool-kind producers.
"""

from __future__ import annotations

from typing import Dict, List, Set, Tuple

import pytest

from repro.core import poly
from repro.core.lowering import (WriteSpec, conv_read_relation,
                                 full_read_relation, pointwise_read_relation,
                                 pool_read_relation)

Point = Tuple[int, ...]


def _brute_safe_trace(W1, R2):
    """After each write iteration: the exact set of safe reader iterations."""
    w_pairs = poly.enumerate_map(W1)
    writes_by_iter: Dict[Point, List[Point]] = {}
    for i, o in w_pairs:
        writes_by_iter.setdefault(i, []).append(o)
    r_pairs = poly.enumerate_map(R2)
    reader_space = sorted({j for j, _ in r_pairs})
    ever = {o for _, o in w_pairs}
    deps: Dict[Point, Set[Point]] = {j: set() for j in reader_space}
    for j, o in r_pairs:
        if o in ever:
            deps[j].add(o)
    stream = [(i, writes_by_iter[i]) for i in sorted(writes_by_iter)]
    seen: Set[Point] = set()
    trace = []
    for _, locs in stream:
        seen.update(locs)
        safe: Set[Point] = set()
        ok = True
        for j in reader_space:
            if not ok:
                break
            if deps[j] <= seen:
                safe.add(j)
            else:
                ok = False
        trace.append(safe)
    return stream, reader_space, trace


def _check_case(W1, R2, array_shape, reader_bounds):
    dep = poly.compute_dep_info(W1, R2)
    # generated-code evaluator (paper §3.4 / §3.5 table variant)
    src, fn = poly.generate_s_evaluator(dep)
    assert "def s_eval(" in src
    frontier = poly.Frontier(dep, fn)
    # compiled vectorized table (the event-engine LCU)
    table = poly.compile_frontier_table(dep, array_shape, reader_bounds)
    bound_rank = -1
    stream, reader_space, trace = _brute_safe_trace(W1, R2)
    for (_, locs), safe_now in zip(stream, trace):
        for loc in locs:
            frontier.observe(loc)
            bound_rank = max(bound_rank, int(table.rank[loc]))
        if table.never_constrains:
            limit = 1 << 62
        elif bound_rank == table.d_lexmax_rank:
            limit = 1 << 62
        else:
            limit = max(bound_rank, table.d_lexmin_rank - 1)
        for j in reader_space:
            want = j in safe_now
            assert frontier.safe(j) == want, (j, safe_now)
            got = poly.iter_rank(j, reader_bounds) <= limit
            assert got == want, ("table", j, limit, want)


CONV_CASES = [
    # h, w, fh, fw, stride, pad, c
    (6, 6, 3, 3, 1, 0, 2),
    (8, 8, 3, 3, 1, 1, 1),
    (8, 7, 3, 2, 2, 1, 2),
    (5, 5, 1, 1, 1, 0, 1),
    (6, 6, 3, 3, 2, 0, 1),
]


@pytest.mark.parametrize("h,w,fh,fw,stride,pad,c", CONV_CASES)
def test_conv_reader_table(h, w, fh, fw, stride, pad, c):
    oh = (h + 2 * pad - fh) // stride + 1
    ow = (w + 2 * pad - fw) // stride + 1
    W1 = WriteSpec("A", "pixel", (c, h, w)).isl_write("WR")
    R2 = conv_read_relation("RD", (oh, ow), (c, h, w), fh, fw, stride, pad)
    _check_case(W1, R2, (c, h, w), (oh, ow))


@pytest.mark.parametrize("h,w,k,stride,c", [(6, 6, 2, 2, 1), (7, 7, 3, 2, 2),
                                            (5, 5, 3, 1, 1)])
def test_pool_reader_table(h, w, k, stride, c):
    oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
    W1 = WriteSpec("A", "pixel", (c, h, w)).isl_write("WR")
    R2 = pool_read_relation("RD", (oh, ow), (c, h, w), k, stride)
    _check_case(W1, R2, (c, h, w), (oh, ow))


@pytest.mark.parametrize("h,w,c", [(5, 5, 2), (4, 6, 1)])
def test_pointwise_reader_table(h, w, c):
    W1 = WriteSpec("A", "pixel", (c, h, w)).isl_write("WR")
    R2 = pointwise_read_relation("RD", (h, w), (c, h, w))
    _check_case(W1, R2, (c, h, w), (h, w))


@pytest.mark.parametrize("h,w,c", [(4, 4, 2), (3, 5, 1)])
def test_full_reader_table(h, w, c):
    """GEMM-style consumer: the table must collapse to wait-for-last-write."""
    W1 = WriteSpec("A", "pixel", (c, h, w)).isl_write("WR")
    R2 = full_read_relation("RD", (c, h, w))
    _check_case(W1, R2, (c, h, w), (1,))


@pytest.mark.parametrize("h,w,k,stride,c", [(8, 8, 2, 2, 1), (9, 9, 3, 2, 2)])
def test_conv_after_pool_producer_table(h, w, k, stride, c):
    """Conv consumer fed by a pool-kind producer (windows finalize late)."""
    ph, pw = (h - k) // stride + 1, (w - k) // stride + 1
    if ph < 3 or pw < 3:
        pytest.skip("too small after pooling")
    W1 = WriteSpec("A", "pool", (c, ph, pw),
                   dict(k=k, stride=stride)).isl_write("WR")
    R2 = conv_read_relation("RD", (ph - 2, pw - 2), (c, ph, pw), 3, 3, 1, 0)
    _check_case(W1, R2, (c, ph, pw), (ph - 2, pw - 2))


def test_s_monotone_in_write_order():
    """S must be single-valued and monotone over the write stream."""
    W1 = WriteSpec("A", "pixel", (2, 6, 6)).isl_write("WR")
    R2 = conv_read_relation("RD", (4, 4), (2, 6, 6), 3, 3, 1, 0)
    dep = poly.compute_dep_info(W1, R2)
    assert dep.S.is_single_valued()
    _, fn = poly.generate_s_evaluator(dep)
    prev = None
    for it, loc in poly.enumerate_map(W1):
        j = fn(*loc)
        if j is None:
            continue
        if prev is not None:
            assert tuple(j) >= prev, (it, loc, j, prev)
        prev = tuple(j)


def test_table_matches_generated_code_exactly():
    """rank[o] == iter_rank(s_eval(o)) for every location (both backends)."""
    W1 = WriteSpec("A", "pixel", (2, 6, 6)).isl_write("WR")
    R2 = conv_read_relation("RD", (4, 4), (2, 6, 6), 3, 3, 1, 0)
    dep = poly.compute_dep_info(W1, R2)
    table = poly.compile_frontier_table(dep, (2, 6, 6), (4, 4))
    _, fn = poly.generate_s_evaluator(dep)
    for ci in range(2):
        for i in range(6):
            for j in range(6):
                sj = fn(ci, i, j)
                r = int(table.rank[ci, i, j])
                if sj is None:
                    assert r == -1, (ci, i, j)
                else:
                    assert r == poly.iter_rank(sj, (4, 4)), (ci, i, j)
    assert table.d_lexmin_rank == poly.iter_rank(dep.D_lexmin, (4, 4))
    assert table.d_lexmax_rank == poly.iter_rank(dep.D_lexmax, (4, 4))
    assert table.nbytes == table.rank.nbytes


def test_listing2_shape():
    """The paper's Listing 2 relation: conv 3x3, stride 1, no padding."""
    R2 = conv_read_relation("CONV_MXV", (4, 4), (3, 6, 6), 3, 3, 1, 0)
    pairs = [(j, o) for j, o in poly.enumerate_map(R2) if j == (0, 0)]
    locs = {o for _, o in pairs}
    assert locs == {(c, i, j) for c in range(3) for i in range(3)
                    for j in range(3)}


# ------------------------------------------------- replication (i mod k) ----
# A k-replicated producer executes the strict subsequence of its iteration
# ranks congruent to r (mod k); its write relation is the full relation
# domain-restricted to that subsequence (poly.restrict_writes_mod).  The
# brute-force oracle needs no change: replica r streams its surviving writes
# in increasing global rank order, exactly what _brute_safe_trace assumes.

def _writer_bounds(W1):
    """Bounding box of the writer iteration domain (dense by construction)."""
    its = sorted({i for i, _ in poly.enumerate_map(W1)})
    nd = len(its[0])
    return tuple(max(i[d] for i in its) + 1 for d in range(nd))


MOD_CASES = [
    # label, W1 builder, R2 builder, array shape, reader bounds
    ("conv", lambda: WriteSpec("A", "pixel", (2, 6, 6)).isl_write("WR"),
     lambda: conv_read_relation("RD", (4, 4), (2, 6, 6), 3, 3, 1, 0),
     (2, 6, 6), (4, 4)),
    ("conv_pad", lambda: WriteSpec("A", "pixel", (1, 6, 6)).isl_write("WR"),
     lambda: conv_read_relation("RD", (6, 6), (1, 6, 6), 3, 3, 1, 1),
     (1, 6, 6), (6, 6)),
    ("pointwise", lambda: WriteSpec("A", "pixel", (2, 5, 5)).isl_write("WR"),
     lambda: pointwise_read_relation("RD", (5, 5), (2, 5, 5)),
     (2, 5, 5), (5, 5)),
    ("broadcast", lambda: WriteSpec("A", "pixel", (2, 4, 4)).isl_write("WR"),
     lambda: full_read_relation("RD", (2, 4, 4)),
     (2, 4, 4), (1,)),
]


def _check_case_conservative(W1, R2, array_shape, reader_bounds):
    """Mod-restricted variant of :func:`_check_case`.

    A reader with no dependency on this residue's writes sits inside the
    dependent-reader domain without being a member; the prefix-frontier
    machinery admits it only once the preceding dependent reader unlocks —
    a sound under-approximation of the brute 'deps ⊆ seen' safe set.  The
    exact contract asserted here: (1) generated code and compiled table
    agree on every decision, (2) machinery-safe ⊆ oracle-safe at every
    step, (3) both admit everything once the residue's stream completes.
    """
    dep = poly.compute_dep_info(W1, R2)
    src, fn = poly.generate_s_evaluator(dep)
    assert "def s_eval(" in src
    frontier = poly.Frontier(dep, fn)
    table = poly.compile_frontier_table(dep, array_shape, reader_bounds)
    bound_rank = -1
    stream, reader_space, trace = _brute_safe_trace(W1, R2)
    for step, ((_, locs), safe_now) in enumerate(zip(stream, trace)):
        for loc in locs:
            frontier.observe(loc)
            bound_rank = max(bound_rank, int(table.rank[loc]))
        if table.never_constrains or bound_rank == table.d_lexmax_rank:
            limit = 1 << 62
        else:
            limit = max(bound_rank, table.d_lexmin_rank - 1)
        last = step == len(stream) - 1
        for j in reader_space:
            got_fr = frontier.safe(j)
            got_tab = poly.iter_rank(j, reader_bounds) <= limit
            assert got_fr == got_tab, ("table/codegen split", j)
            if got_fr:
                assert j in safe_now, ("unsound admission", j)
            if last:
                assert got_fr, ("incomplete at stream end", j)


@pytest.mark.parametrize("label,mkw,mkr,shape,rbounds",
                         MOD_CASES, ids=[c[0] for c in MOD_CASES])
@pytest.mark.parametrize("k", [2, 3])
def test_mod_filtered_relation_vs_oracle(label, mkw, mkr, shape, rbounds, k):
    """Each residue's restricted relation passes the frontier oracle."""
    W1, R2 = mkw(), mkr()
    wb = _writer_bounds(W1)
    for r in range(k):
        W1r = poly.restrict_writes_mod(W1, wb, k, r)
        _check_case_conservative(W1r, R2, shape, rbounds)


@pytest.mark.parametrize("label,mkw,mkr,shape,rbounds",
                         MOD_CASES, ids=[c[0] for c in MOD_CASES])
@pytest.mark.parametrize("k", [2, 3, 4])
def test_mod_residues_partition_writes(label, mkw, mkr, shape, rbounds, k):
    """The k residue relations exactly partition the full write relation."""
    W1 = mkw()
    wb = _writer_bounds(W1)
    full = set(poly.enumerate_map(W1))
    parts = [set(poly.enumerate_map(poly.restrict_writes_mod(W1, wb, k, r)))
             for r in range(k)]
    assert set().union(*parts) == full
    for a in range(k):
        for b in range(a + 1, k):
            assert not (parts[a] & parts[b])
    for r, pr in enumerate(parts):
        assert all(poly.iter_rank(i, wb) % k == r for i, _ in pr)


@pytest.mark.parametrize("label,mkw,mkr,shape,rbounds",
                         MOD_CASES, ids=[c[0] for c in MOD_CASES])
@pytest.mark.parametrize("k", [2, 3])
def test_mod_merged_frontiers_sound_and_complete(label, mkw, mkr, shape,
                                                 rbounds, k):
    """Max-merge semantics over a global write prefix: a consumer admitted
    by ALL k per-replica frontiers is admitted by the single unreplicated
    frontier (soundness — never ahead of the oracle), and once every
    replica's stream completes the merged admission is total."""
    W1, R2 = mkw(), mkr()
    wb = _writer_bounds(W1)
    dep_full = poly.compute_dep_info(W1, R2)
    _, fn = poly.generate_s_evaluator(dep_full)
    full_fr = poly.Frontier(dep_full, fn)
    reps = []
    for r in range(k):
        dep_r = poly.compute_dep_info(
            poly.restrict_writes_mod(W1, wb, k, r), R2)
        _, fr_fn = poly.generate_s_evaluator(dep_r)
        reps.append(poly.Frontier(dep_r, fr_fn))
    by_iter: dict = {}
    for i, o in poly.enumerate_map(W1):
        by_iter.setdefault(i, []).append(o)
    readers = sorted({j for j, _ in poly.enumerate_map(R2)})
    order = sorted(by_iter)
    for step, i in enumerate(order):
        r = poly.iter_rank(i, wb) % k
        for o in by_iter[i]:
            full_fr.observe(o)
            reps[r].observe(o)
        last = step == len(order) - 1
        for j in readers:
            merged = all(fr.safe(j) for fr in reps)
            if merged:
                assert full_fr.safe(j), ("merged admitted early", i, j)
            if last:
                assert merged, ("merged incomplete at stream end", j)
