"""Sharding rules: every spec must be valid (sharded dims divisible by the
mesh axis) for all 10 archs on both production meshes — checked abstractly,
no devices needed."""

from __future__ import annotations

import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import sharding as sh
from repro.configs import archs
from repro.configs.base import get_arch, SHAPES, shapes_for
from repro.models import build_model

MESHES = {
    "single": AbstractMesh((("data", 16), ("model", 16))),
    "multi": AbstractMesh((("pod", 2), ("data", 16), ("model", 16))),
}


def _check_divisible(specs, tree, mesh, where):
    def chk(spec, leaf):
        assert len(spec) <= len(leaf.shape), (where, spec, leaf.shape)
        for i, names in enumerate(spec):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            factor = int(np.prod([mesh.shape[n] for n in names]))
            assert leaf.shape[i] % factor == 0, (
                where, spec, leaf.shape, i, factor)
    jax.tree.map(chk, specs, tree, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", archs.ALL)
def test_param_and_opt_specs_valid(arch, mesh_name):
    cfg = get_arch(arch)
    mesh = MESHES[mesh_name]
    model = build_model(cfg)
    psds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = sh.param_specs(cfg, psds, mesh)
    _check_divisible(pspecs, psds, mesh, f"{arch}/params")
    mspecs = sh.opt_specs(cfg, pspecs, psds, mesh)
    _check_divisible(mspecs, psds, mesh, f"{arch}/moments")


@pytest.mark.parametrize("arch", archs.ALL)
def test_cache_and_batch_specs_valid(arch):
    cfg = get_arch(arch)
    mesh = MESHES["single"]
    model = build_model(cfg)
    for shape_name in shapes_for(cfg):
        shape = SHAPES[shape_name]
        csds = jax.eval_shape(lambda s=shape: model.init_cache(
            s.global_batch, s.seq_len, s.seq_len))
        cspecs = sh.cache_specs(cfg, csds, mesh)
        _check_divisible(cspecs, csds, mesh, f"{arch}/{shape_name}/cache")


def test_model_axis_actually_used():
    """The big weights must shard over 'model' (not silently replicate)."""
    cfg = get_arch("qwen2-7b")
    mesh = MESHES["single"]
    model = build_model(cfg)
    psds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = sh.param_specs(cfg, psds, mesh)
    flat = {"/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path): spec
            for path, spec in
            jax.tree_util.tree_flatten_with_path(
                pspecs, is_leaf=lambda x: isinstance(x, P))[0]}
    assert any("model" in str(s) for s in flat.values())
    assert "model" in str(flat["embed"])
    mlp_specs = [s for k, s in flat.items() if "mlp" in k]
    assert all("model" in str(s) for s in mlp_specs)


def test_zero1_moments_use_data_axis():
    """Non-FSDP archs: ZeRO-1 moments must pick up the 'data' axis."""
    cfg = get_arch("qwen2-7b")
    assert not cfg.fsdp
    mesh = MESHES["single"]
    model = build_model(cfg)
    psds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = sh.param_specs(cfg, psds, mesh)
    mspecs = sh.opt_specs(cfg, pspecs, psds, mesh)
    n_data = sum("data" in str(s) for s in jax.tree.leaves(
        mspecs, is_leaf=lambda x: isinstance(x, P)))
    n_total = len(jax.tree.leaves(mspecs,
                                  is_leaf=lambda x: isinstance(x, P)))
    assert n_data > n_total * 0.5, (n_data, n_total)


def test_long500k_cache_shards_sequence():
    """B=1 at 500k: the KV cache must shard its sequence axis over data."""
    cfg = get_arch("jamba-1.5-large-398b")
    mesh = MESHES["single"]
    model = build_model(cfg)
    csds = jax.eval_shape(lambda: model.init_cache(1, 524_288, 524_288))
    cspecs = sh.cache_specs(cfg, csds, mesh)
    specs = jax.tree.leaves(cspecs, is_leaf=lambda x: isinstance(x, P))
    kv = [s for s in specs if len(s) == 5]
    assert kv and all(s[2] == "data" for s in kv), kv
