"""Pipelined prefill (launch/pipeline_prefill.py): executing the 2-stage
pod pipeline produces the same last-token hidden states as a sequential
full-stack forward (subprocess, 4 host devices, (2 pod, 1 data, 2 model))."""

from __future__ import annotations

import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import smoke_config
from repro.models import lm
from repro.launch.pipeline_prefill import (make_pipelined_prefill,
                                           stage_config)

cfg = smoke_config("llama3.2-3b")
cfg = dataclasses.replace(cfg, n_layers=4, q_chunk=8)
mesh = jax.make_mesh((2, 1, 2), ("pod", "data", "model"))

seq_len, batch, n_micro = 16, 4, 2
b_m = batch // n_micro
rng = np.random.default_rng(0)
tokens = rng.integers(0, cfg.vocab_size,
                      (n_micro, b_m, seq_len)).astype(np.int32)

params = lm.init_lm(cfg, jax.random.key(0))
# stage split: periods [0..1] -> stage 0, [2..3] -> stage 1
n_stages = 2
stage_params = jax.tree.map(
    lambda l: l.reshape((n_stages, l.shape[0] // n_stages) + l.shape[1:]),
    params["positions"])
embed = params["embed"][None]

fn, sds, in_sh, sched = make_pipelined_prefill(cfg, mesh, n_micro,
                                               seq_len, batch)
with mesh:
    got = jax.jit(fn, in_shardings=in_sh)(stage_params, embed,
                                          jnp.asarray(tokens))

# reference: sequential full-stack forward per microbatch
scfg = cfg
want = []
for m in range(n_micro):
    x = params["embed"][jnp.asarray(tokens[m])]
    pos = jnp.broadcast_to(jnp.arange(seq_len)[None], (b_m, seq_len))
    h = lm.run_stack(scfg, params["positions"], x, pos)
    want.append(np.asarray(h[:, -1, :]))
want = np.stack(want)

np.testing.assert_allclose(np.asarray(got, np.float32),
                           want.astype(np.float32), rtol=2e-4, atol=2e-4)
assert sched.n_ticks == n_micro + n_stages - 1
print("PIPELINE_PREFILL_OK", sched.utilization())
"""


def test_pipelined_prefill_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "PIPELINE_PREFILL_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
