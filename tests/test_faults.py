"""Fault injection + graceful degradation (ISSUE 6).

Contracts under test:
  * a fault-free ``FaultSchedule`` is bitwise indistinguishable from no
    schedule at all, in both engines;
  * under an *active* schedule (core death mid-run + a degraded link) the
    reference engine stays the bit-identical oracle for the event engine:
    same failed set, same fail cycles, same counters (cycles / messages /
    bytes / busy / links), same outputs for every successful image;
  * deadlines are the failure detector: a dead core's requests fail at a
    known cycle instead of hanging the simulation;
  * ``RetryPolicy`` backoff matches a hand oracle, and the server's retry
    re-admission cycle math is exactly ``max(fail + backoff, ready)``;
  * recovery remaps around dead cores (the new mapping never touches them)
    and retried requests complete with outputs bitwise equal to a clean run;
  * seeded compute-plane faults (``FaultyPlane`` stuck cells/drift,
    ``NoisyPlane`` Gaussian read noise) are same-seed reproducible, and
    ``FaultyPlane``'s deterministic perturbation preserves engine equality;
  * workload validation rejects NaN/non-positive rates, and
    ``compile_model(..., validate=True)`` names the violated invariant.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import (NoisyPlane, NumpyPlane, Simulator, build_fig2_graph,
                        build_resnet_block_chain, compile_model, make_chip,
                        make_descriptor, place_tenants)
from repro.core.compiler import CompileValidationError, validate_program
from repro.faults import (CoreFault, FaultSchedule, FaultyPlane, LinkFault,
                          RetryPolicy, remap_program, sample_schedule)
from repro.runtime import (ClosedLoopClients, CmServer, poisson_arrivals,
                           uniform_arrivals)

ENGINES = ("reference", "event")


def _images(n, shape=(4, 8, 8), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=shape).astype(np.float32) for _ in range(n)]


def _stat_tuple(s):
    return (s.cycles, s.messages, s.bytes_sent, dict(s.busy),
            dict(s.first_busy), dict(s.last_busy), dict(s.sram_high_water),
            dict(s.gcu_start_cycle), dict(s.completion_cycle),
            dict(s.failed_cycle),
            {k: (v.messages, v.bytes, v.busy) for k, v in s.links.items()})


@pytest.fixture(scope="module")
def fig2():
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    return g, chip, compile_model(g, chip)


@pytest.fixture(scope="module")
def mesh2():
    g = build_resnet_block_chain(4)
    chip = make_chip(6, "banded")
    return g, chip, compile_model(g, chip, chips=2)


# ------------------------------------------------------------- schedule model
def test_schedule_validation_and_timeline():
    with pytest.raises(ValueError):
        CoreFault(core=0, cycle=-1)
    with pytest.raises(ValueError):
        LinkFault(0, 1, cycle=5, latency_add=-1)
    with pytest.raises(ValueError):
        LinkFault(0, 1, cycle=5, width_shrink=0)
    with pytest.raises(ValueError):
        sample_schedule(4, 100, core_fault_rate=1.5)

    s = FaultSchedule(core_faults=(CoreFault(2, 50), CoreFault(2, 30)),
                      link_faults=(
                          LinkFault(0, 1, 40, latency_add=4),
                          LinkFault(0, 1, 80, down=True)))
    assert s.dead_at() == {2: 30}          # earliest death wins
    assert s.dead_cores(by_cycle=29) == frozenset()
    assert s.dead_cores(by_cycle=30) == frozenset({2})

    from repro.core import LinkSpec
    base = LinkSpec(latency=4, width_bytes=64)
    assert s.link_state((0, 1), 39, base) == (False, base)
    down, spec = s.link_state((0, 1), 40, base)
    assert not down and spec.latency == 8 and spec.width_bytes == 64
    down, spec = s.link_state((0, 1), 80, base)
    assert down                            # down is sticky past 80
    assert s.link_state((0, 1), 10_000, base)[0]


def test_sample_schedule_is_seed_deterministic():
    a = sample_schedule(8, 500, core_fault_rate=0.5,
                        links=[(0, 1)], link_fault_rate=1.0, seed=7)
    b = sample_schedule(8, 500, core_fault_rate=0.5,
                        links=[(0, 1)], link_fault_rate=1.0, seed=7)
    assert a == b
    c = sample_schedule(8, 500, core_fault_rate=0.5,
                        links=[(0, 1)], link_fault_rate=1.0, seed=8)
    assert a != c


# ----------------------------------------------- empty schedule == no schedule
@pytest.mark.parametrize("engine", ENGINES)
def test_empty_schedule_bitwise_equals_no_schedule(fig2, engine):
    g, chip, prog = fig2
    imgs = _images(3)
    o0, s0 = Simulator(prog, chip, engine=engine).run(
        imgs, schedule="pipelined", arrivals=[0, 10, 20])
    o1, s1 = Simulator(prog, chip, engine=engine,
                       faults=FaultSchedule()).run(
        imgs, schedule="pipelined", arrivals=[0, 10, 20],
        deadlines=[None, None, None])
    assert _stat_tuple(s0) == _stat_tuple(s1)
    for a, b in zip(o0, o1):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


# --------------------------------------------- engine x engine under faults
def test_engines_bit_identical_under_core_death_and_degraded_link(mesh2):
    """The acceptance scenario: a core dies mid-run AND an inter-chip link
    degrades; both engines agree on every counter, the failed set, and the
    outputs of every successful image."""
    g, chip, prog = mesh2
    imgs = _images(4, shape=g.values["x"].shape, seed=1)
    victim = sorted(prog.cores)[len(prog.cores) // 2]
    faults = FaultSchedule(
        core_faults=(CoreFault(victim, 111),),
        link_faults=(LinkFault(0, 1, 55, latency_add=6, width_shrink=2),))
    deadlines = [534] * 4

    runs = {}
    for engine in ENGINES:
        runs[engine] = Simulator(prog, chip, engine=engine,
                                 faults=faults).run(
            imgs, schedule="pipelined", deadlines=deadlines)
    (o_r, s_r), (o_e, s_e) = runs["reference"], runs["event"]
    assert _stat_tuple(s_r) == _stat_tuple(s_e)
    assert s_r.failed_cycle, "the dead core must fail at least one image"
    for i in range(len(imgs)):
        if i in s_r.failed_cycle:
            continue        # failed outputs are outside the contract
        for k in o_r[i]:
            np.testing.assert_array_equal(o_r[i][k], o_e[i][k])


def test_link_down_drops_messages_identically(mesh2):
    """A downed link drops (not delays) messages sent after the fault; both
    engines count the same reduced traffic and the starved images fail."""
    g, chip, prog = mesh2
    imgs = _images(2, shape=g.values["x"].shape, seed=3)
    faults = FaultSchedule(link_faults=(LinkFault(0, 1, 60, down=True),))
    stats = {}
    for engine in ENGINES:
        _, s = Simulator(prog, chip, engine=engine, faults=faults).run(
            imgs, schedule="pipelined", deadlines=[800, 800])
        assert s.failed_cycle, "cut pipeline must starve the consumers"
        healthy = Simulator(prog, chip, engine=engine).run(
            imgs, schedule="pipelined")[1]
        assert s.messages < healthy.messages
        assert s.bytes_sent < healthy.bytes_sent
        stats[engine] = _stat_tuple(s)
    assert stats["reference"] == stats["event"]


@pytest.mark.parametrize("engine", ENGINES)
def test_core_dead_from_cycle_zero_fails_all_no_hang(fig2, engine):
    g, chip, prog = fig2
    victim = prog.mapping[0]               # first partition's core
    faults = FaultSchedule(core_faults=(CoreFault(victim, 0),))
    imgs = _images(3)
    _, s = Simulator(prog, chip, engine=engine, faults=faults).run(
        imgs, schedule="pipelined", arrivals=[0, 10, 20],
        deadlines=[200, 210, 220])
    assert s.failed_cycle == {0: 200, 1: 210, 2: 220}
    assert not s.completion_cycle
    assert s.cycles <= 221, "run must end at the last deadline, not hang"


def test_faults_validated_against_hardware(fig2):
    g, chip, prog = fig2
    with pytest.raises(ValueError):        # core id off-chip
        Simulator(prog, chip, faults=FaultSchedule(
            core_faults=(CoreFault(99, 0),)))
    with pytest.raises(ValueError):        # link faults need a mesh
        Simulator(prog, chip, faults=FaultSchedule(
            link_faults=(LinkFault(0, 1, 0, down=True),)))


# ------------------------------------------------------------ retry + backoff
def test_retry_policy_hand_oracle():
    p = RetryPolicy(max_retries=4, backoff_cycles=10, backoff_factor=3,
                    max_backoff_cycles=50)
    assert [p.backoff(a) for a in (1, 2, 3, 4)] == [10, 30, 50, 50]
    with pytest.raises(ValueError):
        p.backoff(0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_factor=0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_cycles=100, max_backoff_cycles=10)


def test_server_retry_readmission_cycle_math():
    """The retry arrival is exactly max(fail_cycle + backoff(attempt),
    hardware_ready); with remap disabled by an unfixable fault the backoff
    alone drives re-admission, checked against the policy arithmetic."""
    chip = make_chip(8, "all_to_all")
    pl = place_tenants([build_fig2_graph()], chip)
    victim = sorted(pl.programs[0].cores)[1]
    faults = FaultSchedule(core_faults=(CoreFault(victim, 30),))
    retry = RetryPolicy(max_retries=2, backoff_cycles=16, backoff_factor=2)
    srv = CmServer(pl, chip, faults=faults, deadline=250, retry=retry,
                   reprogram_cost_cycles=40)
    rep = srv.serve_images(_images(2), arrivals=[0, 10])
    assert rep.goodput == 1.0 and rep.n_retries == 2
    [ev] = rep.remap_events
    assert ev["ok"] and victim in ev["dead_cores"]
    assert rep.reprogram_cycles == 40 * ev["n_crossbars"]
    # both requests failed at their deadlines; detection is the latest one
    detect = 10 + 250
    ready = detect + 1 + rep.reprogram_cycles
    # attempt 1 backoff = 16, so both re-admissions were gated by `ready`
    for r in rep.requests:
        assert r.attempts == 1
        assert r.gcu_start >= ready
        # first-attempt verdict is retained alongside the final success
        assert r.fail_cycle == r.arrival + 250 and r.succeeded


def test_retries_exhaust_then_fail_permanently():
    chip = make_chip(4, "all_to_all")
    pl = place_tenants([build_fig2_graph()], chip)
    # kill every core: remap is impossible, retries must burn out
    faults = FaultSchedule(core_faults=tuple(
        CoreFault(c, 0) for c in range(4)))
    retry = RetryPolicy(max_retries=2, backoff_cycles=8)
    srv = CmServer(pl, chip, faults=faults, deadline=100, retry=retry)
    rep = srv.serve_images(_images(2), arrivals=[0, 5])
    assert rep.goodput == 0.0
    assert all(r.failed and r.attempts == 2 for r in rep.requests)
    assert rep.n_retries == 4
    assert all(not e["ok"] for e in rep.remap_events)


def test_fault_injection_requires_deadline():
    chip = make_chip(4, "all_to_all")
    pl = place_tenants([build_fig2_graph()], chip)
    with pytest.raises(ValueError, match="deadline"):
        CmServer(pl, chip,
                 faults=FaultSchedule(core_faults=(CoreFault(0, 0),)))


# ------------------------------------------------------------------ remapping
def test_remap_excludes_failed_core_end_to_end():
    chip = make_chip(8, "all_to_all")
    pl = place_tenants([build_fig2_graph()], chip)
    old_cores = set(pl.programs[0].cores)
    victim = sorted(old_cores)[0]
    res = remap_program(build_fig2_graph(), chip=chip,
                        dead_cores=[victim])
    assert victim not in res.cores
    assert res.n_crossbars == 2            # fig-2: two conv partitions

    # server-level: after recovery the live program avoids the dead core
    faults = FaultSchedule(core_faults=(CoreFault(victim, 20),))
    srv = CmServer(pl, chip, faults=faults, deadline=250,
                   retry=RetryPolicy(max_retries=1))
    rep = srv.serve_images(_images(3), arrivals=[0, 10, 20])
    assert rep.goodput == 1.0
    assert victim not in set(srv.programs[0].cores)
    # and the remapped outputs are bitwise the clean answers
    clean = CmServer(place_tenants([build_fig2_graph()], chip), chip) \
        .serve_images(_images(3), arrivals=[0, 10, 20])
    for r, c in zip(rep.requests, clean.requests):
        for k in c.output:
            np.testing.assert_array_equal(r.output[k], c.output[k])


def test_remap_respects_reserved_cores():
    chip = make_chip(8, "all_to_all")
    res = remap_program(build_fig2_graph(), chip=chip,
                        dead_cores=[0], reserved_cores=[1, 2, 3])
    assert not (set(res.cores) & {0, 1, 2, 3})


# --------------------------------------------------------- compute-plane noise
def test_noisy_plane_same_seed_bit_reproducible():
    rng = np.random.default_rng(0)
    m = rng.normal(size=(16, 16)).astype(np.float32)
    v = rng.normal(size=16).astype(np.float32)
    desc = make_descriptor(m, "gemm")
    a = NoisyPlane(sigma=0.05, seed=42)
    b = NoisyPlane(sigma=0.05, seed=42)
    ya = [a.mxv_one(desc, v) for _ in range(3)]
    yb = [b.mxv_one(desc, v) for _ in range(3)]
    for x, y in zip(ya, yb):
        np.testing.assert_array_equal(x, y)
    # per-call draws: consecutive calls differ (it is read noise)
    assert not np.array_equal(ya[0], ya[1])
    # different seed differs
    yc = NoisyPlane(sigma=0.05, seed=43).mxv_one(desc, v)
    assert not np.array_equal(ya[0], yc)
    # sigma=0 is exactly the inner plane
    y0 = NoisyPlane(sigma=0.0, seed=1).mxv_one(desc, v)
    np.testing.assert_array_equal(y0, NumpyPlane().mxv_one(desc, v))
    with pytest.raises(ValueError):
        NoisyPlane(sigma=-0.1)
    with pytest.raises(ValueError):
        NoisyPlane(sigma=float("nan"))


def test_faulty_plane_deterministic_and_content_addressed():
    rng = np.random.default_rng(1)
    m = rng.normal(size=(12, 20)).astype(np.float32)
    v = rng.normal(size=20).astype(np.float32)
    desc = make_descriptor(m, "gemm")
    a = FaultyPlane(stuck_fraction=0.2, stuck_value=0.0, drift_sigma=0.05,
                    seed=9)
    b = FaultyPlane(stuck_fraction=0.2, stuck_value=0.0, drift_sigma=0.05,
                    seed=9)
    ya, yb = a.mxv_one(desc, v), b.mxv_one(desc, v)
    np.testing.assert_array_equal(ya, yb)
    # unlike read noise, the perturbation is *frozen*: repeat calls agree
    np.testing.assert_array_equal(ya, a.mxv_one(desc, v))
    assert not np.array_equal(ya, NumpyPlane().mxv_one(desc, v))
    with pytest.raises(ValueError):
        FaultyPlane(stuck_fraction=1.5)


@pytest.mark.parametrize("plane_ctor", [
    lambda: FaultyPlane(stuck_fraction=0.1, drift_sigma=0.02, seed=5)])
def test_faulty_plane_engines_stay_bit_identical(fig2, plane_ctor):
    """The frozen perturbation is batch-invariant, so crossbar faults do
    not break reference/event equality."""
    g, chip, prog = fig2
    imgs = _images(2)
    outs = {}
    for engine in ENGINES:
        o, _ = Simulator(prog, chip, engine=engine,
                         compute_plane=plane_ctor()).run(
            imgs, schedule="pipelined")
        outs[engine] = o
    for a, b in zip(outs["reference"], outs["event"]):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


# ------------------------------------------------------- workload validation
def test_workload_rate_validation():
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError):
            poisson_arrivals(4, bad)
        with pytest.raises(ValueError):
            uniform_arrivals(4, bad)


def test_closed_loop_validation_and_sweep_guard():
    with pytest.raises(ValueError):
        ClosedLoopClients(n_clients=0, requests_per_client=2, think_cycles=5)
    with pytest.raises(ValueError):
        ClosedLoopClients(n_clients=1, requests_per_client=2,
                          think_cycles=-1)
    with pytest.raises(ValueError):
        ClosedLoopClients(n_clients=1, requests_per_client=2,
                          think_cycles=5, max_sweeps=0)

    chip = make_chip(4, "all_to_all")
    prog = compile_model(build_fig2_graph(), chip)
    srv = CmServer(prog, chip)
    clients = ClosedLoopClients(n_clients=2, requests_per_client=2,
                                think_cycles=10, max_sweeps=1)
    with pytest.raises(RuntimeError, match="max_sweeps"):
        clients.run(srv, _images(4))
    # with the default bound the same population converges
    ok = ClosedLoopClients(n_clients=2, requests_per_client=2,
                           think_cycles=10)
    rep = ok.run(srv, _images(4))
    assert len(rep.requests) == 4


# -------------------------------------------------------- compile validation
def test_compile_validate_passes_on_good_programs(fig2, mesh2):
    g, chip, _ = fig2
    compile_model(g, chip, validate=True)
    gm, chipm, progm = mesh2
    validate_program(progm)                # mesh program carries its mesh


def test_compile_validate_names_violated_invariant(fig2):
    g, chip, _ = fig2
    prog = compile_model(g, chip)

    bad = dataclasses.replace(prog, mapping=dict(prog.mapping),
                              cores={99: next(iter(prog.cores.values()))})
    with pytest.raises(CompileValidationError) as ei:
        validate_program(bad, chip)
    assert ei.value.invariant == "cores-on-chip"

    # cut a link out of the chip: the mapped edge loses its connection
    narrow = dataclasses.replace(
        chip, edges=frozenset(e for e in chip.edges
                              if e != (prog.mapping[0], prog.mapping[1])))
    with pytest.raises(CompileValidationError) as ei:
        validate_program(prog, narrow)
    assert ei.value.invariant == "cut-edge-link"

    tiny = dataclasses.replace(
        chip, core=dataclasses.replace(chip.core, sram_bytes=8))
    with pytest.raises(CompileValidationError) as ei:
        validate_program(prog, tiny)
    assert ei.value.invariant == "sram-fits"

    with pytest.raises(ValueError):
        validate_program(prog)             # single-chip needs the chip


def test_remap_dead_replica_core_bitwise_clean():
    """A replica core dies: remap keeps the full replica group on the
    survivors and the recovered outputs are bitwise the clean answer."""
    from repro.core import build_lenet_like, compile_model

    g = build_lenet_like()
    chip = make_chip(8, "all_to_all")
    plan = {"conv1": 4}
    prog = compile_model(g, chip, replicate=plan)
    # kill the core hosting replica residue 1 (partition index 1)
    victim = prog.mapping[1]
    res = remap_program(g, chip=chip, dead_cores=[victim], replicate=plan)
    assert victim not in res.cores
    # full replica set survives (8 cores, 1 dead, 7 partitions fit)
    assert len(res.program.pgraph.replica_groups[0]) == 4
    validate_program(res.program, chip)
    rng = np.random.default_rng(7)
    imgs = [rng.standard_normal((1, 12, 12)).astype(np.float32)
            for _ in range(3)]
    clean, _ = Simulator(compile_model(g, chip), chip).run(imgs)
    for engine in ("event", "reference"):
        rec, _ = Simulator(res.program, chip, engine=engine).run(imgs)
        for a, b in zip(clean, rec):
            for k in a:
                np.testing.assert_array_equal(a[k], b[k])


def test_remap_replica_degrades_to_k_minus_1():
    """Two dead cores leave no room for k=4 + tail: remap falls back to a
    re-lowered k=3 round-robin, still bitwise value-correct."""
    from repro.core import build_lenet_like, compile_model

    g = build_lenet_like()
    chip = make_chip(8, "all_to_all")
    res = remap_program(g, chip=chip, dead_cores=[2, 5],
                        replicate={"conv1": 4})
    assert not (set(res.cores) & {2, 5})
    group = res.program.pgraph.replica_groups[0]
    assert len(group) == 3                 # degraded k-1 round-robin
    validate_program(res.program, chip)
    rng = np.random.default_rng(8)
    imgs = [rng.standard_normal((1, 12, 12)).astype(np.float32)
            for _ in range(3)]
    clean, _ = Simulator(compile_model(g, chip), chip).run(imgs)
    rec, _ = Simulator(res.program, chip).run(imgs)
    for a, b in zip(clean, rec):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])
