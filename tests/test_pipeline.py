"""Polyhedral pipeline (core/pipeline.py): schedules derived from the
Appendix-A automata match a brute-force earliest-start oracle, and the
shard_map execution matches the sequential reference.

The execution test needs >1 device, so it runs in a subprocess with
``--xla_force_host_platform_device_count`` (tests themselves must see 1
device — the dry-run is the only place 512 devices are forced).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # gated: optional test dep
from hypothesis import given, settings, strategies as st

from repro.core import pipeline


@settings(max_examples=30, deadline=None)
@given(
    kinds=st.lists(st.sampled_from(pipeline.EDGE_KINDS), min_size=1,
                   max_size=4),
    n_items=st.integers(1, 8),
)
def test_schedule_matches_bruteforce(kinds, n_items):
    """Three-way: the vectorized frontier-table schedule == the generated
    LCU automata schedule == the explicit-dependency brute force."""
    sched = pipeline.derive_schedule(kinds, n_items)
    want = pipeline.reference_schedule_bruteforce(kinds, n_items)
    np.testing.assert_array_equal(sched.start, want)
    automata = pipeline.derive_schedule_automata(kinds, n_items)
    np.testing.assert_array_equal(automata.start, want)
    np.testing.assert_array_equal(sched.table, automata.table)


def test_pointwise_schedule_is_classic_pipeline():
    """Pointwise edges: stage s starts item t at tick t + s (skew 1)."""
    sched = pipeline.derive_schedule(["pointwise"] * 3, 6)
    for s in range(4):
        for t in range(6):
            assert sched.start[s, t] == t + s
    # steady state: all stages busy -> utilization n/(n+S-1)
    assert sched.utilization() == pytest.approx(6 * 4 / (4 * 9))


def test_full_schedule_degenerates_to_layer_at_a_time():
    """A bidirectional (encoder) edge forces wait-for-last-write."""
    sched = pipeline.derive_schedule(["full"], 4)
    # stage 1 cannot start any item before stage 0 finished item 3 (tick 3)
    assert sched.start[1, 0] == 4
    assert (sched.start[1] == np.arange(4) + 4).all()


def test_causal_schedule_skew():
    """Causal edge: consumer item t needs producer items <= t — same
    frontier as pointwise for a 1-item-per-tick producer."""
    sched = pipeline.derive_schedule(["causal"], 5)
    assert (sched.start[1] == np.arange(5) + 1).all()


def test_makespan_advantage():
    """Pipelined makespan n+S-1 << sequential n*S for deep pipelines."""
    kinds = ["pointwise"] * 7
    n = 16
    sched = pipeline.derive_schedule(kinds, n)
    assert sched.n_ticks == n + 7
    assert sched.n_ticks < n * 8 / 3


_EXEC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import pipeline

    mesh = jax.make_mesh((4,), ("stage",))
    n_stages, n_items, dim = 4, 6, 16
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(n_stages, dim, dim)) / np.sqrt(dim),
                    jnp.float32)
    xs = jnp.asarray(rng.normal(size=(n_items, dim)), jnp.float32)

    def fn(w, x):
        return jnp.tanh(x @ w)

    sched = pipeline.derive_schedule(["pointwise"] * (n_stages - 1), n_items)
    out = pipeline.pipeline_apply([fn] * n_stages, W, xs, sched, mesh)
    want = pipeline.sequential_apply([fn] * n_stages, W, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("PIPELINE_EXEC_OK", sched.n_ticks)
""")


def test_pipeline_execution_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _EXEC_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "PIPELINE_EXEC_OK" in r.stdout, r.stdout + r.stderr
