"""Bottleneck-stage replication (ISSUE 7).

The replication contract: a k-replicated stage executes iteration rank
``i`` on replica ``i mod k`` (round-robin), consumers gate each iteration
on ALL per-replica frontiers, and the result is bitwise the unreplicated
program's — across engine x compute plane x schedule — with only the
timing (and therefore pipe utilization) changing.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.compiler import (CompileValidationError, compile_model,
                                 validate_program)
from repro.core.graph import build_lenet_like, build_tiny_transformer
from repro.core.hwspec import make_chip
from repro.core.lowering import lower
from repro.core.mapping import map_partitions
from repro.core.partition import (GCU_PARTITION, PartitionError,
                                  partition_graph, partition_iterations,
                                  plan_replication, replicate_partitions)
from repro.core.simulator import Simulator


def _images(shape, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(shape).astype(np.float32) for _ in range(n)]


def _stat_key(st):
    return (st.cycles, st.messages, st.bytes_sent, dict(st.busy),
            dict(st.sram_high_water))


# ------------------------------------------------------------- partitioning
def test_replicate_partitions_layout():
    pg = replicate_partitions(partition_graph(build_lenet_like()),
                              {"conv1": 4})
    assert pg.replica_groups == {0: (0, 1, 2, 3)}
    members = [pg.partitions[i] for i in range(4)]
    assert [p.repl_r for p in members] == [0, 1, 2, 3]
    assert all(p.repl_k == 4 for p in members)
    # replicas share the conv1 node objects; the pool tail follows
    assert len({id(members[0].nodes[0])} |
               {id(p.nodes[0]) for p in members}) == 1
    assert pg.partitions[4].nodes[0].op == "maxpool2d"
    # no intra-group edges: replicas never communicate
    for (s, d) in pg.edges:
        if s != GCU_PARTITION:
            assert not (pg.partitions[s].repl_group == 0
                        and pg.partitions[d].repl_group == 0)


def test_replicate_k_exceeding_iterations_rejected():
    pg = partition_graph(build_lenet_like())
    n = partition_iterations(pg, pg.partitions[0])
    with pytest.raises(PartitionError):
        replicate_partitions(pg, {"conv1": n + 1})


def test_replicate_unknown_node_rejected():
    pg = partition_graph(build_lenet_like())
    with pytest.raises(PartitionError):
        replicate_partitions(pg, {"nope": 2})


def test_plan_replication_targets_bottleneck():
    pg = partition_graph(build_lenet_like())
    plan = plan_replication(pg, 8, dma_pixels_per_cycle=4)
    # conv1 (100 iterations vs 9 and 1 downstream) is the bottleneck
    assert set(plan) == {"conv1"} and plan["conv1"] > 1
    # a tight budget yields no plan rather than an infeasible one
    assert plan_replication(pg, 3, dma_pixels_per_cycle=4) == {}


# ------------------------------------------------- bitwise oracle (tentpole)
@pytest.mark.parametrize("engine", ["event", "reference"])
@pytest.mark.parametrize("plane", ["numpy", "reference"])
@pytest.mark.parametrize("schedule", ["pipelined", "sequential"])
def test_replicated_lenet_bitwise_oracle(engine, plane, schedule):
    """Replicated lenet (k=4) == unreplicated, engine x plane x schedule."""
    g = build_lenet_like()
    chip = make_chip(8, "all_to_all")
    base = compile_model(g, chip)
    prog = compile_model(g, chip, replicate={"conv1": 4}, validate=True)
    imgs = _images((1, 12, 12), 3)
    ob, _ = Simulator(base, chip, engine=engine,
                      compute_plane=plane).run(imgs, schedule=schedule)
    orp, _ = Simulator(prog, chip, engine=engine,
                       compute_plane=plane).run(imgs, schedule=schedule)
    for a, b in zip(ob, orp):
        for v in a:
            assert np.array_equal(a[v], b[v]), v


@pytest.mark.parametrize("schedule", ["pipelined", "sequential"])
def test_replicated_engines_counter_identical(schedule):
    """Both engines agree on every counter for the replicated program."""
    g = build_lenet_like()
    chip = make_chip(8, "all_to_all")
    prog = compile_model(g, chip, replicate={"conv1": 4})
    imgs = _images((1, 12, 12), 4)
    out = {}
    for engine in ("event", "reference"):
        o, st = Simulator(prog, chip, engine=engine).run(imgs,
                                                         schedule=schedule)
        out[engine] = (o, _stat_key(st))
    for a, b in zip(out["event"][0], out["reference"][0]):
        for v in a:
            assert np.array_equal(a[v], b[v]), v
    assert out["event"][1] == out["reference"][1]


def test_replication_improves_utilization_and_throughput_per_core():
    g = build_lenet_like()
    chip = make_chip(8, "all_to_all")
    imgs = _images((1, 12, 12), 8)
    _, sb = Simulator(compile_model(g, chip), chip).run(imgs)
    prog = compile_model(g, chip, replicate={"conv1": 3})
    _, sr = Simulator(prog, chip).run(imgs)
    assert sr.mean_utilization() > sb.mean_utilization()
    # throughput per core: images / (cycles * busy cores)
    tb = len(imgs) / (sb.cycles * len(sb.busy))
    tr = len(imgs) / (sr.cycles * len(sr.busy))
    assert tr > tb


def test_replicated_transformer_bitwise():
    """Broadcast consumer (qk reads all of q_proj) over a replica group."""
    g = build_tiny_transformer()
    chip = make_chip(16, "all_to_all")
    base = compile_model(g, chip)
    prog = compile_model(g, chip,
                         replicate={"q_proj": 2, "k_proj": 2, "v_proj": 2},
                         validate=True)
    imgs = _images((8, 4, 1), 3)
    for engine in ("event", "reference"):
        ob, _ = Simulator(base, chip, engine=engine).run(imgs)
        orp, _ = Simulator(prog, chip, engine=engine).run(imgs)
        for a, b in zip(ob, orp):
            for v in a:
                assert np.array_equal(a[v], b[v]), (engine, v)


def test_direct_pool_replication_bitwise():
    """A split-off pool stage is itself replicable (direct-mode gather)."""
    g = build_lenet_like()
    chip = make_chip(10, "all_to_all")
    base = compile_model(g, chip)
    prog = compile_model(g, chip, replicate={"conv1": 4, "pool1": 2},
                         validate=True)
    imgs = _images((1, 12, 12), 3)
    for engine in ("event", "reference"):
        ob, _ = Simulator(base, chip, engine=engine).run(imgs)
        orp, _ = Simulator(prog, chip, engine=engine).run(imgs)
        for a, b in zip(ob, orp):
            for v in a:
                assert np.array_equal(a[v], b[v]), (engine, v)


def test_auto_replication_end_to_end():
    """compile_model(replicate="auto") plans against the chip's stream rate
    and stays bitwise clean."""
    g = build_lenet_like()
    chip = make_chip(18, "all_to_all", dma_pixels_per_cycle=16)
    base = compile_model(g, chip)
    prog = compile_model(g, chip, replicate="auto", validate=True)
    assert len(prog.cores) > len(base.cores)
    imgs = _images((1, 12, 12), 8)
    ob, sb = Simulator(base, chip).run(imgs)
    orp, sr = Simulator(prog, chip).run(imgs)
    for a, b in zip(ob, orp):
        for v in a:
            assert np.array_equal(a[v], b[v]), v
    assert sr.mean_utilization() >= 0.85 > sb.mean_utilization()


# ------------------------------------------------------- validate_program
def test_validate_flags_broken_replica_group():
    g = build_lenet_like()
    chip = make_chip(8, "all_to_all")
    prog = compile_model(g, chip, replicate={"conv1": 4})
    validate_program(prog, chip)
    # sabotage: two replicas claim the same residue
    c0 = prog.mapping[0]
    saved = prog.cores[c0].repl_r
    prog.cores[c0].repl_r = 1
    with pytest.raises(CompileValidationError) as ei:
        validate_program(prog, chip)
    assert ei.value.invariant == "replica-group"
    prog.cores[c0].repl_r = saved
    # sabotage: a consumer loses one per-replica dependency automaton
    dst = prog.mapping[4]
    lc = prog.cores[dst].lcu["relu1:out"]
    lc.deps = lc.deps[:-1]
    with pytest.raises(CompileValidationError) as ei:
        validate_program(prog, chip)
    assert ei.value.invariant == "replica-group"


def test_replica_group_mapping_symmetry_broken():
    """Replica core ids are strictly increasing (symmetry breaking)."""
    pg = replicate_partitions(partition_graph(build_lenet_like()),
                              {"conv1": 4})
    chip = make_chip(8, "banded", k=7)
    mapping = map_partitions(pg, chip)
    cores = [mapping[p] for p in pg.replica_groups[0]]
    assert cores == sorted(cores)
    prog = lower(pg, mapping)
    validate_program(prog, chip)
