"""Design-space autotuner (ISSUE 10): determinism, funnel accounting,
committed-artifact round-trips, and the search-neighborhood primitives.

The determinism tests are the contract the CI ``autotune-smoke`` job
rests on: same (model, target, workload, budget, seed, space) must give a
bitwise-identical ``TuneResult`` — which also means the committed
``configs/tuned/*.json`` artifacts must reproduce on *either* polyhedral
backend, so nothing backend- or wall-clock-shaped may leak into them.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.analysis import verify_program
from repro.core import (PartitionError, Simulator, build_lenet_like,
                        build_resnet_block_chain, chip_cuts_of,
                        compile_model, cut_neighbors, make_chip, make_mesh,
                        partition_chips, partition_graph, place_tenants,
                        replicable_stages)
from repro.tune import (SearchSpace, TRIAL_STAGES, TuneConfig, TuneResult,
                        TuneWorkload, ZOO, artifact_dict, artifact_json,
                        autotune, load_tuned, resolve_tuned, tune_zoo_entry)

CHIP = dict(topology="all_to_all", dma_pixels_per_cycle=16)


def _small_search(seed=0, budget=8):
    return autotune(
        build_lenet_like(), make_chip(18, **CHIP),
        TuneWorkload(n_images=3), budget=budget, seed=seed,
        space=SearchSpace(max_repl_k=16, batch=4, shortlist=2),
        label="lenet")


# ------------------------------------------------------------- determinism
def test_same_seed_bitwise_identical():
    a, b = _small_search(seed=7), _small_search(seed=7)
    assert a.to_json() == b.to_json()          # bytes, not just scores
    assert a.best == b.best and a.best_cycles == b.best_cycles


def test_different_seed_still_valid():
    # different seeds may walk differently but both must satisfy the
    # result invariants and agree with a re-simulation of their winner
    r = _small_search(seed=11)
    assert r.best_cycles <= r.baseline_cycles
    assert any(t.stage == "simulated" for t in r.trials)


def test_no_wallclock_or_backend_in_result_json():
    d = json.loads(_small_search().to_json())
    dumped = json.dumps(d)
    for forbidden in ("_ms", "wall", "time", "islpy", "fisl", "backend"):
        assert forbidden not in dumped, forbidden


# ------------------------------------------------------ funnel accounting
def test_funnel_accounting():
    r = _small_search(budget=10)
    counts = r.counts
    assert counts["candidates"] == len(r.trials) <= 10
    assert counts["candidates"] == sum(counts[s] for s in TRIAL_STAGES)
    for t in r.trials:
        assert t.stage in TRIAL_STAGES
        if t.stage == "simulated":
            # only simulated trials carry a score (and a bottleneck tag)
            assert t.cycles is not None and t.cycles > 0
            assert t.detail.startswith("bottleneck=")
        else:
            assert t.cycles is None            # never touched the engine
        if t.stage in ("compile-error", "prefilter-discard"):
            assert t.static_interval is None   # discarded before ranking
            assert t.detail                    # discard reason is named
    # trial indices are the consideration order, dense from 0
    assert [t.index for t in r.trials] == list(range(len(r.trials)))


def test_prefilter_discards_are_never_simulated(monkeypatch):
    # inject a pre-filter rule that rejects every candidate wider than the
    # unreplicated base program (>3 cores on lenet), then assert the
    # funnel honored it: discarded configs never reached the engine
    from repro.analysis.diagnostics import AnalysisDiagnostic
    from repro.tune import search as search_mod
    real = search_mod.prefilter_program

    def narrow_only(prog, chip=None, *, max_inflight=1):
        report = real(prog, chip, max_inflight=max_inflight)
        if len(prog.cores) > 3:
            report.diagnostics.insert(0, AnalysisDiagnostic(
                check="test-width", severity="error",
                message=f"rejected: {len(prog.cores)} cores"))
        return report

    monkeypatch.setattr(search_mod, "prefilter_program", narrow_only)
    r = _small_search(budget=8)
    assert r.counts["prefilter-discard"] >= 1
    for t in r.trials:
        if t.stage == "prefilter-discard":
            assert t.cycles is None
            assert "test-width" in t.detail
        if t.stage == "simulated":
            assert t.n_cores is not None and t.n_cores <= 3
    assert r.best.key() == "base"     # only the base config survived


def test_multi_tenant_tenant_order_moves_score_correctly():
    """Tenant-order moves permute the compiled program list; the
    evaluator must remap its per-image tenant indices to the permuted
    slots.  The tenants are differently shaped on purpose: a stale index
    would feed lenet images to the resnet program and crash on reshape
    (or, shapes permitting, silently score the wrong model)."""
    graphs = [build_lenet_like(), build_resnet_block_chain(2)]
    chip = make_chip(12, **CHIP)
    workload = TuneWorkload(n_images=2)
    r = autotune(graphs, chip, workload, budget=4, seed=0,
                 space=SearchSpace(batch=2, shortlist=2))
    swapped = [t for t in r.trials
               if t.config.tenant_order == (1, 0)
               and t.stage == "simulated"]
    assert swapped, "the tenant-swap move must be simulated, not crash"
    # pin the score: rebuild the swapped placement directly and simulate
    # the same seeded images against their slots in the *permuted* list
    placement = place_tenants([graphs[1], graphs[0]], chip)
    rng = np.random.default_rng(workload.seed)
    per_graph = [
        [rng.normal(size=tuple(int(x) for x in
                               g.values[g.inputs[0]].shape)
                    ).astype(np.float32)
         for _ in range(workload.n_images)]
        for g in graphs]
    images, tenants = [], []
    for i in range(workload.n_images):
        for t, imgs in enumerate(per_graph):
            images.append(imgs[i])
            tenants.append({1: 0, 0: 1}[t])   # graph idx -> slot in (1, 0)
    sim = Simulator(list(placement.programs), chip, check_raw=False,
                    engine="event", compute_plane="numpy")
    _, stats = sim.run(images, schedule=workload.schedule, tenants=tenants,
                       stalls=True)
    assert int(stats.cycles) == swapped[0].cycles


def test_multi_tenant_same_seed_bitwise_identical():
    graphs = [build_lenet_like(), build_resnet_block_chain(2)]
    chip = make_chip(12, **CHIP)
    runs = [autotune(graphs, chip, TuneWorkload(n_images=2), budget=4,
                     seed=3, space=SearchSpace(batch=2, shortlist=2))
            for _ in range(2)]
    assert runs[0].to_json() == runs[1].to_json()


def test_infeasible_space_raises():
    # an SRAM-starved chip rejects even the base config at mapping time:
    # the search must fail loudly, not return a fabricated result
    chip = make_chip(18, sram_bytes=64, **CHIP)
    with pytest.raises(PartitionError, match="no candidate"):
        autotune(build_lenet_like(), chip, TuneWorkload(n_images=2),
                 budget=4, seed=0, space=SearchSpace(batch=2, shortlist=1))


def test_budget_is_a_hard_cap():
    r = _small_search(budget=5)
    assert len(r.trials) <= 5
    with pytest.raises(ValueError, match="budget"):
        _small_search(budget=1)


# ------------------------------------------- committed-artifact round-trip
@pytest.mark.parametrize("name", sorted(ZOO))
def test_tuned_artifact_round_trip(name):
    """configs/tuned/<name>.json → compile_model(tune=) → verify_program
    clean → simulated cycles == the recorded score, on whichever
    polyhedral backend this leg runs."""
    art = load_tuned(name)
    entry = ZOO[name]
    graph, chip = entry.build(), entry.chip()
    prog = compile_model(graph, chip, tune=name)
    report = verify_program(prog, chip)
    assert not report.errors(), [d.message for d in report.errors()]
    rng = np.random.default_rng(entry.workload.seed)
    shape = tuple(int(x) for x in graph.values[graph.inputs[0]].shape)
    images = [rng.normal(size=shape).astype(np.float32)
              for _ in range(entry.workload.n_images)]
    _, stats = Simulator(prog, chip, check_raw=False).run(
        images, schedule=entry.workload.schedule)
    assert stats.cycles == art["cycles"]
    assert art["cycles"] <= art["baseline"]["cycles"]


def _dummy_result(label="custom", cfg=None):
    cfg = cfg or TuneConfig(replicate=(("conv1", 2),))
    return TuneResult(label=label, seed=0, budget=2, space=SearchSpace(),
                      workload=TuneWorkload(), best=cfg, best_cycles=100,
                      baseline=cfg, baseline_cycles=100, trials=[])


def test_resolve_tuned_forms():
    cfg = TuneConfig(replicate=(("conv1", 2),))
    assert resolve_tuned(cfg) is cfg
    # a TuneResult resolves to its winning config (the compile_model
    # docstring promises this form)
    assert resolve_tuned(_dummy_result(cfg=cfg)) is cfg
    art = load_tuned("lenet")
    assert resolve_tuned(art) == resolve_tuned("lenet")
    # artifact path form
    p = pathlib.Path(__file__).resolve().parents[1] / "configs" / "tuned" \
        / "lenet.json"
    assert resolve_tuned(p) == resolve_tuned("lenet")
    with pytest.raises(FileNotFoundError, match="committed configs"):
        load_tuned("no-such-model")


def test_artifact_rejects_non_zoo_label():
    # autotune's default label is "model" — artifact_dict must explain
    # that artifacts only name zoo entries, not die on a bare KeyError
    with pytest.raises(ValueError, match="zoo"):
        artifact_dict(_dummy_result(label="model"))


def test_tune_config_json_round_trip():
    cfg = TuneConfig(replicate=(("a", 3), ("b", 2)), chips=2,
                     topology="ring", chip_cuts=(3, 8),
                     tenant_order=(1, 0))
    assert TuneConfig.from_json_dict(cfg.to_json_dict()) == cfg
    assert TuneConfig.from_json_dict(json.loads(
        json.dumps(cfg.to_json_dict()))) == cfg


# ------------------------------------------------- neighborhood primitives
def test_cut_neighbors_and_explicit_cuts():
    pg = partition_graph(build_resnet_block_chain(2))
    mesh = make_mesh(2, chip=make_chip(8, **CHIP))
    assign = partition_chips(pg, mesh)
    cuts = chip_cuts_of(assign, mesh.n_chips)
    assert len(cuts) == mesh.n_chips - 1   # one boundary between 2 chips
    # pinning the DP's own cuts must reproduce its assignment
    assert partition_chips(pg, mesh, cuts=cuts) == assign
    n_parts = len(pg.partitions)
    neighbors = list(cut_neighbors(cuts, n_parts))
    assert neighbors
    for nb in neighbors:
        assert nb != tuple(cuts)
        assert all(0 <= b <= n_parts for b in nb)
        assert list(nb) == sorted(nb)
    with pytest.raises(PartitionError, match="cut"):
        partition_chips(pg, mesh, cuts=(0, 1))   # wrong boundary count


def test_replicable_stages_names_match_replicate_keys():
    g = build_lenet_like()
    stages = replicable_stages(partition_graph(g))
    assert stages, "lenet must expose replicable stages"
    anchor, iters = stages[0]
    assert iters > 1
    chip = make_chip(18, **CHIP)
    prog = compile_model(g, chip, replicate={anchor: 2})
    assert prog is not None


def test_tune_kwarg_applies_mesh_and_plan():
    # the resnet4 artifact records a 2-chip mesh: tune= must materialize it
    chip = ZOO["resnet4"].chip()
    prog = compile_model(build_resnet_block_chain(4), chip, tune="resnet4")
    art = load_tuned("resnet4")
    assert art["config"]["chips"] == 2
    assert prog.mesh is not None and prog.mesh.n_chips == 2
    # explicit arguments win over the artifact
    prog1 = compile_model(build_resnet_block_chain(4), chip,
                          tune=TuneConfig())
    assert prog1.mesh is None


def test_artifact_json_is_canonical():
    # regenerating the artifact bytes from the recorded search must match
    # the committed file exactly (the CI autotune-smoke gate, in-process);
    # run the cheaper lenet recipe only — resnet4 is covered nightly by CI
    result = tune_zoo_entry("lenet")
    committed = (pathlib.Path(__file__).resolve().parents[1] / "configs"
                 / "tuned" / "lenet.json").read_text()
    assert artifact_json(result) == committed


def test_result_json_parses_and_counts_match():
    r = _small_search()
    d = json.loads(r.to_json())
    assert d["counts"] == r.counts
    assert d["best_cycles"] == r.best_cycles
    assert len(d["trials"]) == len(r.trials)
    assert isinstance(r, TuneResult)
