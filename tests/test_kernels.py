"""Per-kernel Pallas validation: shape/dtype sweeps vs the ref.py oracles.

All kernels run in ``interpret=True`` mode (CPU container; TPU is the
target).  Tolerances are f32-accumulation tolerances.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # gated: optional test dep
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.conv2d import crossbar_conv2d
from repro.kernels.decode_attn import flash_decode
from repro.kernels.flash_attn import flash_attention
from repro.kernels.mamba_scan import selective_scan
from repro.kernels.mxv import crossbar_mxv, crossbar_mxv_int8

RNG = np.random.default_rng(1234)


# ------------------------------------------------------------------ mxv
@pytest.mark.parametrize("b,m,n,bb,bm,bn", [
    (1, 128, 128, 8, 128, 128),
    (8, 256, 384, 8, 128, 128),
    (16, 512, 256, 4, 256, 64),
    (2, 64, 32, 2, 64, 32),        # sub-MXU sizes still correct in interpret
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_mxv_sweep(b, m, n, bb, bm, bn, dtype):
    w = RNG.normal(size=(m, n)).astype(np.float32)
    wq, sc = ref.quantize_crossbar(w)
    x = jnp.asarray(RNG.normal(size=(b, n)).astype(np.float32), dtype)
    y = crossbar_mxv(x, wq, sc, bb=bb, bm=bm, bn=bn)
    want = ref.crossbar_mxv_ref(x, wq, sc)
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,m,n", [(4, 128, 128), (8, 256, 512)])
def test_mxv_int8_sweep(b, m, n):
    w = RNG.normal(size=(m, n)).astype(np.float32)
    x = RNG.normal(size=(b, n)).astype(np.float32)
    wq, ws = ref.quantize_crossbar(w)
    xq, xs = ref.quantize_vec(x)
    y = crossbar_mxv_int8(xq, xs, wq, ws)
    want = ref.crossbar_mxv_int8_ref(xq, xs, wq, ws)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-6, atol=1e-6)  # exact int path


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([1, 4]), m=st.sampled_from([128, 256]),
       n=st.sampled_from([128, 256]))
def test_mxv_property(b, m, n):
    w = RNG.normal(size=(m, n)).astype(np.float32)
    wq, sc = ref.quantize_crossbar(w)
    x = RNG.normal(size=(b, n)).astype(np.float32)
    y = crossbar_mxv(x, wq, sc)
    want = ref.crossbar_mxv_ref(x, wq, sc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ conv2d
@pytest.mark.parametrize("c,h,w,fl,fh,fw,stride,pad", [
    (3, 8, 8, 8, 3, 3, 1, 1),
    (4, 12, 12, 16, 3, 3, 2, 0),
    (1, 6, 6, 4, 1, 1, 1, 0),
    (2, 9, 7, 8, 3, 3, 1, 2),
])
def test_conv2d_sweep(c, h, w, fl, fh, fw, stride, pad):
    x = RNG.normal(size=(c, h, w)).astype(np.float32)
    wf = RNG.normal(size=(fl, c * fh * fw)).astype(np.float32)
    wq, sc = ref.quantize_crossbar(wf)
    y = crossbar_conv2d(x, wq, sc, stride=stride, pad=pad, fh=fh, fw=fw)
    want = ref.crossbar_conv2d_ref(x, wq, sc, stride, pad, fh, fw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- flash attn
@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,bq,bk", [
    (1, 4, 4, 128, 128, 64, 64, 64),      # MHA
    (2, 8, 2, 256, 256, 32, 128, 128),    # GQA 4:1
    (1, 4, 1, 128, 128, 64, 64, 32),      # MQA
    (2, 4, 2, 64, 256, 32, 64, 64),       # cross/kv-longer (decode-chunk)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, bq, bk, causal):
    q = RNG.normal(size=(b, hq, sq, d)).astype(np.float32)
    k = RNG.normal(size=(b, hkv, sk, d)).astype(np.float32)
    v = RNG.normal(size=(b, hkv, sk, d)).astype(np.float32)
    y = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    y = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


# ------------------------------------------------------------- decode attn
@pytest.mark.parametrize("b,hq,hkv,s,d,bk,length", [
    (1, 8, 2, 256, 64, 128, 200),
    (4, 4, 4, 512, 32, 128, 512),
    (2, 16, 2, 256, 64, 64, 17),
])
def test_flash_decode_sweep(b, hq, hkv, s, d, bk, length):
    q = RNG.normal(size=(b, hq, d)).astype(np.float32)
    k = RNG.normal(size=(b, hkv, s, d)).astype(np.float32)
    v = RNG.normal(size=(b, hkv, s, d)).astype(np.float32)
    y = flash_decode(q, k, v, length, bk=bk)
    want = ref.decode_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# -------------------------------------------------------------- mamba scan
@pytest.mark.parametrize("b,l,d,n,bd,bl", [
    (1, 64, 32, 8, 16, 16),
    (2, 128, 64, 16, 32, 64),
    (1, 32, 16, 4, 16, 32),
])
def test_selective_scan_sweep(b, l, d, n, bd, bl):
    u = RNG.normal(size=(b, l, d)).astype(np.float32) * 0.5
    dt = np.abs(RNG.normal(size=(b, l, d))).astype(np.float32) * 0.1
    a = -np.abs(RNG.normal(size=(d, n))).astype(np.float32)
    bb = RNG.normal(size=(b, l, n)).astype(np.float32)
    cc = RNG.normal(size=(b, l, n)).astype(np.float32)
    dsk = RNG.normal(size=(d,)).astype(np.float32)
    y = selective_scan(u, dt, a, bb, cc, dsk, bd=bd, bl=bl)
    want = ref.selective_scan_ref(u, dt, a, bb, cc, dsk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_selective_scan_state_carries_across_chunks():
    """Chunked kernel must match the oracle when L spans several chunks."""
    b, l, d, n = 1, 256, 16, 4
    u = RNG.normal(size=(b, l, d)).astype(np.float32) * 0.3
    dt = np.abs(RNG.normal(size=(b, l, d))).astype(np.float32) * 0.05
    a = -np.abs(RNG.normal(size=(d, n))).astype(np.float32)
    bb = RNG.normal(size=(b, l, n)).astype(np.float32)
    cc = RNG.normal(size=(b, l, n)).astype(np.float32)
    dsk = RNG.normal(size=(d,)).astype(np.float32)
    y = selective_scan(u, dt, a, bb, cc, dsk, bd=16, bl=32)  # 8 chunks
    want = ref.selective_scan_ref(u, dt, a, bb, cc, dsk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ----------------------------------------------------- int8 flash decode
@pytest.mark.parametrize("b,hq,hkv,s,d,bk,length", [
    (2, 8, 2, 256, 64, 128, 200),
    (1, 4, 4, 128, 128, 64, 128),
    (3, 6, 2, 512, 32, 128, 1),
])
def test_flash_decode_int8_sweep(b, hq, hkv, s, d, bk, length):
    from repro.kernels.decode_attn_int8 import flash_decode_int8
    q = RNG.normal(size=(b, hq, d)).astype(np.float32)
    k = RNG.normal(size=(b, hkv, s, d)).astype(np.float32) * 2
    v = RNG.normal(size=(b, hkv, s, d)).astype(np.float32)

    def quant(x):
        am = np.abs(x).max(axis=-1, keepdims=True)
        sc = np.where(am > 0, am / 127.0, 1.0).astype(np.float32)
        xq = np.clip(np.round(x / sc), -127, 127).astype(np.int8)
        return jnp.asarray(xq), jnp.asarray(sc)

    k8, ks = quant(k)
    v8, vs = quant(v)
    got = flash_decode_int8(jnp.asarray(q), k8, ks, v8, vs, length, bk=bk)
    want = ref.decode_int8_ref(jnp.asarray(q), k8, ks, v8, vs, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_int8_matches_fp_within_quant_noise():
    """The int8 kernel's output tracks the *unquantized* decode closely."""
    from repro.kernels.decode_attn_int8 import flash_decode_int8
    b, hq, hkv, s, d = 2, 8, 2, 256, 64
    q = RNG.normal(size=(b, hq, d)).astype(np.float32)
    k = RNG.normal(size=(b, hkv, s, d)).astype(np.float32)
    v = RNG.normal(size=(b, hkv, s, d)).astype(np.float32)
    am_k = np.abs(k).max(-1, keepdims=True) / 127.0
    am_v = np.abs(v).max(-1, keepdims=True) / 127.0
    k8 = np.clip(np.round(k / am_k), -127, 127).astype(np.int8)
    v8 = np.clip(np.round(v / am_v), -127, 127).astype(np.int8)
    got = flash_decode_int8(jnp.asarray(q), jnp.asarray(k8),
                            jnp.asarray(am_k.astype(np.float32)),
                            jnp.asarray(v8),
                            jnp.asarray(am_v.astype(np.float32)), 256)
    want = ref.decode_ref(jnp.asarray(q), jnp.asarray(k),
                          jnp.asarray(v), 256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.05, atol=0.05)
