"""Per-arch smoke tests (deliverable f): reduced same-family configs run a
forward/train step + prefill/decode on CPU, asserting shapes and no NaNs."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.configs.base import get_arch, smoke_config, shapes_for
from repro.models import build_model
from repro.optim import adamw_init
from repro.train import TrainState, make_train_step

B, S = 2, 32


def _batch(cfg, rng):
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        if cfg.is_encdec:
            batch["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", archs.ALL)
def test_full_config_dims(arch):
    """The registered config reproduces the assignment table exactly."""
    cfg = get_arch(arch)
    table = {
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    l, d, h, kv, ff, v = table[arch]
    assert cfg.n_layers == l and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v
    if arch == "qwen2-moe-a2.7b":
        assert cfg.moe.n_experts == 60 and cfg.moe.top_k == 4
        assert cfg.moe.n_shared == 4
    if arch == "qwen3-moe-235b-a22b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 8
    if arch == "jamba-1.5-large-398b":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 2
        assert cfg.pattern().count("A") * 7 == cfg.pattern().count("M")
    if arch == "falcon-mamba-7b":
        assert cfg.ssm.state == 16 and cfg.attn_free


@pytest.mark.parametrize("arch", archs.ALL)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.key(0))
    state = TrainState(params, adamw_init(params, cfg.adam_dtype),
                       jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(model))
    batch = _batch(cfg, rng)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) != float(m1["loss"])  # params actually moved
    assert int(state.step) == 2
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", archs.ALL)
def test_prefill_decode_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg, rng)
    batch.pop("labels")
    logits, cache = model.prefill(params, batch, S + 8)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    if cfg.embed_inputs and not cfg.is_encdec:
        step_in = params["embed"][tok]
    else:
        step_in = tok
    logits2, cache = model.decode_step(params, cache, step_in)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(np.asarray(cache["length"])[0]) == S + 1


@pytest.mark.parametrize("arch", ["llama3.2-3b", "falcon-mamba-7b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode over a short sequence must match the parallel
    (prefill) forward — the KV/SSM cache path is numerically consistent."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 8)), jnp.int32)

    params = model.init(jax.random.key(2))
    # parallel forward logits at the last position
    logits_par, _ = model.prefill(params, {"tokens": toks}, 16)

    # incremental: prefill first 4, then decode tokens 4..7 teacher-forced
    logits_inc, cache = model.prefill(params, {"tokens": toks[:, :4]}, 16)
    for t in range(4, 8):
        logits_inc, cache = model.decode_step(params, cache, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits_inc),
                               np.asarray(logits_par),
                               rtol=2e-3, atol=2e-3)


def test_shapes_for_assignment_coverage():
    """40 assigned cells: 32 runnable + 8 documented long_500k skips."""
    total, runnable = 0, 0
    for a in archs.ALL:
        cfg = get_arch(a)
        run = shapes_for(cfg)
        total += 4
        runnable += len(run)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in run
        else:
            assert "long_500k" not in run
    assert total == 40 and runnable == 32


def test_param_counts_match_scale():
    """Sanity: param_count lands in the right ballpark per arch name."""
    expect = {"llama3.2-3b": (2e9, 5e9),
              "qwen2-7b": (6e9, 9e9),
              "phi3-medium-14b": (12e9, 16e9),
              "falcon-mamba-7b": (6e9, 9e9),
              "qwen3-moe-235b-a22b": (200e9, 270e9),
              "jamba-1.5-large-398b": (330e9, 460e9)}
    for a, (lo, hi) in expect.items():
        n = get_arch(a).param_count()
        assert lo < n < hi, (a, n)
