"""Transformer encoder blocks on the CM pipeline (ISSUE 5).

Covers the three contracts the op-coverage expansion must hold:

  * functional — ``build_tiny_transformer`` outputs match the numpy graph
    oracle across engine × compute-plane (and with the explicit-transpose
    attention variant, and scaled out to ``chips=2``, and co-resident with a
    CNN tenant);
  * accounting — reference↔event bit-identity of outputs AND of
    cycles/messages/bytes/busy/SRAM-high-water on every schedule;
  * polyhedral — frontier-table contract tests for the new dependency
    patterns (row-wise layernorm/softmax = pointwise finalize-per-row;
    dynamic matmul's broadcast ``b`` operand = all-or-nothing), checked
    against a brute-force dependency oracle on whichever backend is active
    (CI runs both the exact islpy backend and the ``fisl`` fallback).
"""

from __future__ import annotations

from typing import Dict, List, Set, Tuple

import numpy as np
import pytest

from repro.core import (DynMatmulDescriptor, Simulator, build_lenet_like,
                        build_tiny_transformer, compile_model,
                        execute_reference, make_chip, make_mesh,
                        place_tenants, poly)
from repro.core.lowering import (WriteSpec, broadcast_read_relation,
                                 pointwise_read_relation)

Point = Tuple[int, ...]

SEQ, D_MODEL = 4, 8


def _images(n: int, shape=(D_MODEL, SEQ, 1), seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=shape).astype(np.float32) for _ in range(n)]


@pytest.fixture(scope="module")
def xfmr():
    graph = build_tiny_transformer()
    chip = make_chip(12, "banded")
    return graph, chip, compile_model(graph, chip)


# ------------------------------------------------------- functional contract
@pytest.mark.parametrize("engine", ["event", "reference"])
@pytest.mark.parametrize("plane", ["numpy", "reference"])
def test_outputs_match_oracle(xfmr, engine, plane):
    graph, chip, prog = xfmr
    images = _images(2)
    sim = Simulator(prog, chip, check_raw=True, engine=engine,
                    compute_plane=plane)
    outs, _ = sim.run(images, schedule="pipelined")
    for img, out in zip(images, outs):
        want = execute_reference(graph, {"x": img})
        for v in want:
            np.testing.assert_allclose(out[v], want[v], rtol=1e-5, atol=1e-5)


def test_explicit_transpose_variant_matches():
    """matmul(q, transpose(k)) computes bit-for-bit the same attention as
    matmul(q, k, transpose_b=True) — the runtime matrix assembled from the
    transposed SRAM array carries identical values."""
    chip = make_chip(12, "banded")
    images = _images(2)
    outs = []
    for xt in (False, True):
        graph = build_tiny_transformer(explicit_transpose=xt)
        sim = Simulator(compile_model(graph, chip), chip, check_raw=True)
        outs.append(sim.run(images)[0])
    for oa, ob in zip(*outs):
        for v in oa:
            np.testing.assert_array_equal(oa[v], ob[v])


def test_post_gemm_softmax_1d():
    """softmax/layernorm over a 1-D post-gemm tensor (the 'full' write-spec
    branch) — classifier head with a probability output."""
    rng = np.random.default_rng(3)
    from repro.core import Graph
    g = Graph()
    x = g.add_input("x", (2, 4, 4))
    w = g.add_weight("w", rng.normal(size=(3, 2, 3, 3), scale=0.4))
    wf = g.add_weight("wf", rng.normal(size=(5, 3), scale=0.3))
    h = g.conv2d("conv", x, w)
    h = g.maxpool2d("pool", h)
    h = g.flatten("flat", h)
    h = g.gemm("fc", h, wf)
    out = g.softmax("probs", h)
    g.mark_output(out)
    g.validate()
    chip = make_chip(4, "all_to_all")
    prog = compile_model(g, chip)
    images = _images(2, shape=(2, 4, 4))
    for engine in ("event", "reference"):
        sim = Simulator(prog, chip, check_raw=True, engine=engine)
        outs, _ = sim.run(images)
        for img, out_ in zip(images, outs):
            want = execute_reference(g, {"x": img})
            for v in want:
                np.testing.assert_allclose(out_[v], want[v],
                                           rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- accounting contract
@pytest.mark.parametrize("schedule", ["pipelined", "sequential"])
def test_engine_bit_identity(xfmr, schedule):
    graph, chip, prog = xfmr
    images = _images(3)
    runs = {}
    for engine in ("event", "reference"):
        sim = Simulator(prog, chip, check_raw=True, engine=engine)
        runs[engine] = sim.run(images, schedule=schedule)
    (eo, es), (ro, rs) = runs["event"], runs["reference"]
    assert es.cycles == rs.cycles
    assert es.messages == rs.messages
    assert es.bytes_sent == rs.bytes_sent
    assert dict(es.busy) == dict(rs.busy)
    assert dict(es.sram_high_water) == dict(rs.sram_high_water)
    assert es.gcu_start_cycle == rs.gcu_start_cycle
    assert es.completion_cycle == rs.completion_cycle
    for oa, ob in zip(eo, ro):
        for v in oa:
            np.testing.assert_array_equal(oa[v], ob[v])


def test_plane_bit_identity(xfmr):
    """Stacked numpy plane ≡ per-iteration reference plane, bit for bit —
    including the DPU dynamic-matmul batch path."""
    graph, chip, prog = xfmr
    images = _images(3)
    outs = {}
    for plane in ("numpy", "reference"):
        sim = Simulator(prog, chip, check_raw=False, compute_plane=plane)
        outs[plane] = sim.run(images)[0]
    for oa, ob in zip(outs["numpy"], outs["reference"]):
        for v in oa:
            np.testing.assert_array_equal(oa[v], ob[v])


def test_chips2_bitwise_equal_single_chip():
    graph = build_tiny_transformer()
    chip = make_chip(6, "banded")
    mesh = make_mesh(2, chip=chip)
    prog2 = compile_model(graph, chip, chips=2)
    assert prog2.dma_streams, "2-chip compile must cut the partition chain"
    wide = make_chip(12, "banded")
    prog1 = compile_model(graph, wide)
    images = _images(2)
    link_stats = {}
    for engine in ("event", "reference"):
        o2, s2 = Simulator(prog2, mesh, check_raw=True,
                           engine=engine).run(images)
        o1, _ = Simulator(prog1, wide, check_raw=True,
                          engine=engine).run(images)
        for oa, ob in zip(o2, o1):
            for v in oa:
                np.testing.assert_array_equal(oa[v], ob[v])
        link_stats[engine] = {k: (ls.messages, ls.bytes, ls.busy)
                              for k, ls in s2.links.items()}
        assert link_stats[engine], "cut edges must ride the mesh links"
    assert link_stats["event"] == link_stats["reference"]


def test_tenant_coresidency_bitwise():
    """Transformer + CNN co-resident on one chip: shared GCU/DMA only, so
    each tenant's outputs are bitwise those of the same program alone."""
    chip = make_chip(16, "banded")
    gx, gl = build_tiny_transformer(), build_lenet_like()
    tp = place_tenants([gx, gl], chip)
    ix = _images(2)
    il = _images(2, shape=(1, 12, 12), seed=7)
    sim = Simulator(tp.programs, chip, check_raw=True)
    outs, _ = sim.run([ix[0], il[0], ix[1], il[1]], tenants=[0, 1, 0, 1])
    alone_x, _ = Simulator(tp.programs[0], chip, check_raw=True).run(ix)
    alone_l, _ = Simulator(tp.programs[1], chip, check_raw=True).run(il)
    for got, want in ((outs[0], alone_x[0]), (outs[2], alone_x[1]),
                      (outs[1], alone_l[0]), (outs[3], alone_l[1])):
        for v in got:
            np.testing.assert_array_equal(got[v], want[v])


# ------------------------------------------------------------ lowering shape
def test_dyn_matmul_descriptor_and_reshape_alias(xfmr):
    graph, chip, prog = xfmr
    mm_cores = [c for c in prog.cores.values()
                if any(n.op == "matmul" for n in c.dpu_nodes)]
    assert len(mm_cores) == 2                      # QKᵀ and attn·V
    for c in mm_cores:
        assert c.xbar_node is None and c.compute is None
        (desc,) = c.dyn_compute.values()
        assert isinstance(desc, DynMatmulDescriptor)
        assert desc.a_value in c.lcu and desc.b_value in c.lcu
    qk = next(d for c in mm_cores for d in c.dyn_compute.values()
              if d.transpose_b)
    assert qk.a_value == "q_proj:out" and qk.b_value == "k_proj:out"
    assert qk.scale == pytest.approx(1.0 / np.sqrt(8.0))
    # the reshape head is an alias: the classifier core's LCU reads the
    # residual stream directly
    cls = next(c for c in prog.cores.values()
               if c.xbar_node is not None and c.xbar_node.name == "cls")
    assert set(cls.lcu) == {"res2:out"}


# ------------------------------------------- frontier-table contract (poly)
def _brute_safe_trace(W1, R2):
    """After each write iteration: the exact set of safe reader iterations
    (prefix property included — same oracle as test_frontier_tables)."""
    w_pairs = poly.enumerate_map(W1)
    writes_by_iter: Dict[Point, List[Point]] = {}
    for i, o in w_pairs:
        writes_by_iter.setdefault(i, []).append(o)
    r_pairs = poly.enumerate_map(R2)
    reader_space = sorted({j for j, _ in r_pairs})
    ever = {o for _, o in w_pairs}
    deps: Dict[Point, Set[Point]] = {j: set() for j in reader_space}
    for j, o in r_pairs:
        if o in ever:
            deps[j].add(o)
    stream = [(i, writes_by_iter[i]) for i in sorted(writes_by_iter)]
    seen: Set[Point] = set()
    trace = []
    for _, locs in stream:
        seen.update(locs)
        safe: Set[Point] = set()
        ok = True
        for j in reader_space:
            if not ok:
                break
            if deps[j] <= seen:
                safe.add(j)
            else:
                ok = False
        trace.append(safe)
    return stream, reader_space, trace


def _check_case(W1, R2, array_shape, reader_bounds):
    dep = poly.compute_dep_info(W1, R2)
    _, fn = poly.generate_s_evaluator(dep)
    frontier = poly.Frontier(dep, fn)
    table = poly.compile_frontier_table(dep, array_shape, reader_bounds)
    bound_rank = -1
    stream, reader_space, trace = _brute_safe_trace(W1, R2)
    for (_, locs), safe_now in zip(stream, trace):
        for loc in locs:
            frontier.observe(loc)
            bound_rank = max(bound_rank, int(table.rank[loc]))
        if table.never_constrains or bound_rank == table.d_lexmax_rank:
            limit = poly.INF_RANK
        else:
            limit = max(bound_rank, table.d_lexmin_rank - 1)
        for j in reader_space:
            want = j in safe_now
            assert frontier.safe(j) == want, (j, safe_now)
            assert (poly.iter_rank(j, reader_bounds) <= limit) == want, \
                ("table", j, limit, want)
    return table


@pytest.mark.parametrize("c,t", [(3, 4), (4, 6), (1, 5)])
def test_rowwise_pointwise_table(c, t):
    """layernorm/softmax pattern: pixel producer over (C, T, 1), pointwise
    reader over (T, 1) — each row finalizes exactly at its own iteration."""
    W1 = WriteSpec("A", "pixel", (c, t, 1)).isl_write("WR")
    R2 = pointwise_read_relation("RD", (t, 1), (c, t, 1))
    table = _check_case(W1, R2, (c, t, 1), (t, 1))
    for ci in range(c):
        for ti in range(t):
            assert int(table.rank[ci, ti, 0]) == ti
    assert table.d_lexmin_rank == 0
    assert table.d_lexmax_rank == t - 1


@pytest.mark.parametrize("c,h,rb", [(3, 4, (4, 1)), (4, 4, (6, 1)),
                                    (2, 5, (2, 1))])
def test_broadcast_operand_table(c, h, rb):
    """Dynamic matmul's ``b`` operand / transpose input: every reader
    iteration needs the whole array, so the table is all-or-nothing — only
    the producer's last write advances the frontier, and it saturates."""
    W1 = WriteSpec("A", "pixel", (c, h, 1)).isl_write("WR")
    R2 = broadcast_read_relation("RD", rb, (c, h, 1))
    table = _check_case(W1, R2, (c, h, 1), rb)
    total = rb[0] * rb[1]
    assert table.d_lexmin_rank == 0
    assert table.d_lexmax_rank == total - 1
    # only the locations of the last write iteration unlock anything
    assert (table.rank[:, :h - 1, :] == -1).all()
    assert (table.rank[:, h - 1, 0] == total - 1).all()


def test_matmul_self_operand_union():
    """matmul(x, x): the same array read pointwise (operand a) AND broadcast
    (operand b).  The union relation must collapse to the broadcast gate."""
    c, h, rb = 3, 4, (4, 1)
    W1 = WriteSpec("A", "pixel", (c, h, 1)).isl_write("WR")
    R2 = pointwise_read_relation("RD", rb, (c, h, 1)).union(
        broadcast_read_relation("RD", rb, (c, h, 1)))
    dep = poly.compute_dep_info(W1, R2)
    table = poly.compile_frontier_table(dep, (c, h, 1), rb)
    bcast = poly.compile_frontier_table(
        poly.compute_dep_info(
            W1, broadcast_read_relation("RD", rb, (c, h, 1))),
        (c, h, 1), rb)
    np.testing.assert_array_equal(table.rank, bcast.rank)
    assert table.d_lexmin_rank == bcast.d_lexmin_rank
    assert table.d_lexmax_rank == bcast.d_lexmax_rank


def test_broadcast_after_pool_producer():
    """Broadcast consumer fed by a pool-kind producer (windows finalize
    late): the gate must wait for the *pool-order* last write."""
    c, h, w, k, s = 2, 6, 6, 2, 2
    ph, pw = (h - k) // s + 1, (w - k) // s + 1
    W1 = WriteSpec("A", "pool", (c, ph, pw),
                   dict(k=k, stride=s)).isl_write("WR")
    R2 = broadcast_read_relation("RD", (3, 1), (c, ph, pw))
    _check_case(W1, R2, (c, ph, pw), (3, 1))
