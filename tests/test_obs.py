"""Observability layer (ISSUE 9): stalls, traces, telemetry.

Contracts under test:
  * **zero-cost off switch** — ``run(trace=None, stalls=False)`` (the
    default) is bitwise identical to a run with observability on: same
    outputs, same counters; tracing must never perturb the timing model;
  * **accounting identity** — per core, ``busy + sum(stall categories)
    == total run cycles``, checked for every attributed run;
  * **engine equality** — the event engine's reconstructed
    ``StallBreakdown`` is bit-equal to the reference engine's per-cycle
    oracle across schedules, replication, multi-chip meshes and faults;
  * **byte-determinism** — same-seed runs serialize byte-identical trace
    files, and both engines serialize the *same* bytes;
  * **critical path** — ``critical_path`` names the stage the
    partitioner's static cost model (``static_bottleneck``) targets;
  * **serving telemetry** — ``CmServer.serve`` populates the metrics
    registry consistently with the report, ``to_json``/``to_table`` are
    well-formed, and fault recovery shows up as remap/retry trace events.
"""

from __future__ import annotations

import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (Simulator, build_lenet_like,
                        build_resnet_block_chain, compile_model, make_chip)
from repro.faults import CoreFault, FaultSchedule, LinkFault, RetryPolicy
from repro.obs import (DEAD, FAILED, GCU_STARVED, LINK_DELAY, Histogram,
                       MetricsRegistry, StallBreakdown, TraceRecorder,
                       critical_path, dep_key, in_flight, static_bottleneck)
from repro.runtime import CmServer

ENGINES = ("reference", "event")


def _images(n, shape=(1, 12, 12), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=shape).astype(np.float32) for _ in range(n)]


def _stat_tuple(s):
    return (s.cycles, s.messages, s.bytes_sent, dict(s.busy),
            dict(s.first_busy), dict(s.last_busy), dict(s.sram_high_water),
            dict(s.gcu_start_cycle), dict(s.completion_cycle),
            dict(s.failed_cycle),
            {k: (v.messages, v.bytes, v.busy) for k, v in s.links.items()})


@pytest.fixture(scope="module")
def lenet():
    g = build_lenet_like()
    chip = make_chip(8, "all_to_all")
    return g, chip, compile_model(g, chip)


@pytest.fixture(scope="module")
def mesh2():
    g = build_resnet_block_chain(4)
    chip = make_chip(6, "banded")
    return g, chip, compile_model(g, chip, chips=2)


# ----------------------------------------------------------- primitive units
def test_dep_key_and_in_flight():
    assert dep_key("conv1:out", 2) == "dep-wait:conv1:out:p2"
    assert dep_key("x", -1) == GCU_STARVED
    # open interval: a message in the air at t, not its send/arrive cycles
    assert in_flight([(10, 14)], 12)
    assert not in_flight([(10, 14)], 10)
    assert not in_flight([(10, 14)], 14)
    assert not in_flight(None, 12)
    assert not in_flight([], 12)


def test_breakdown_accounting_check():
    ok = StallBreakdown(cycles=10, busy={0: 4},
                        stalls={0: {GCU_STARVED: 6}}, stage_of_core={0: "a"})
    ok.check()
    bad = StallBreakdown(cycles=10, busy={0: 4},
                         stalls={0: {GCU_STARVED: 5}}, stage_of_core={0: "a"})
    with pytest.raises(AssertionError, match="core 0"):
        bad.check()
    assert ok.total(GCU_STARVED) == 6
    assert ok.by_stage()["a"]["busy"] == 4


def test_histogram_and_registry():
    h = Histogram()
    for v in (5, 1, 3):
        h.observe(v)
    assert (h.count, h.total, h.percentile(0), h.percentile(100)) \
        == (3, 9, 1, 5)
    assert h.percentile(50) == 3
    m = MetricsRegistry()
    m.counter("a").inc(2)
    m.counter("a").inc()
    m.gauge("g").set(7)
    m.histogram("h").observe(4)
    snap = m.snapshot()
    assert snap["counters"] == {"a": 3}
    assert snap["gauges"] == {"g": 7}
    assert snap["histograms"]["h"]["count"] == 1
    assert json.loads(m.to_json()) == snap


# --------------------------------------------------- zero-cost off contract
@pytest.mark.parametrize("engine", ENGINES)
def test_observability_off_is_bitwise_free(lenet, engine):
    _, chip, prog = lenet
    images = _images(3)
    sim = Simulator(prog, chip, engine=engine)
    o_plain, s_plain = sim.run(images)
    o_obs, s_obs = sim.run(images, stalls=True, trace=TraceRecorder())
    assert s_plain.stalls is None
    assert _stat_tuple(s_plain) == _stat_tuple(s_obs)
    for a, b in zip(o_plain, o_obs):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


# ------------------------------------------- engine-equality + accounting
def _breakdown_pair(prog, chip, images, **kw):
    out = []
    for engine in ENGINES:
        sim = Simulator(prog, chip, engine=engine)
        _, stats = sim.run(images, stalls=True, **kw)
        stats.stalls.check()          # busy + sum(stalls) == run cycles
        out.append(stats.stalls)
    return out


@pytest.mark.parametrize("schedule", ("pipelined", "sequential"))
def test_breakdown_engine_equality(lenet, schedule):
    _, chip, prog = lenet
    ref, ev = _breakdown_pair(prog, chip, _images(3), schedule=schedule)
    assert ref == ev
    assert ref.gcu_busy > 0


def test_breakdown_engine_equality_admission(lenet):
    g, chip, _ = lenet
    prog = compile_model(g, chip)
    ref, ev = _breakdown_pair(prog, chip, _images(4),
                              arrivals=[0, 50, 60, 200],
                              max_inflight=2)
    assert ref == ev


def test_breakdown_engine_equality_replicated(lenet):
    g, chip, _ = lenet
    prog = compile_model(g, chip, replicate={"conv1": 2})
    ref, ev = _breakdown_pair(prog, chip, _images(4))
    assert ref == ev
    # replica stalls name the specific blocking producer partition
    deps = {c for per in ref.stalls.values() for c in per
            if c.startswith("dep-wait:")}
    assert deps, ref.stalls


def test_breakdown_engine_equality_mesh_faults(mesh2):
    _, chip, prog = mesh2
    from repro.core import make_mesh
    mesh = make_mesh(2, chip=chip)
    images = _images(4, shape=(4, 8, 8))
    victim = sorted(prog.cores)[2]
    cases = [
        (None, None),
        (FaultSchedule(core_faults=(CoreFault(victim, cycle=150),),
                       link_faults=(LinkFault(0, 1, 100, latency_add=6),)),
         [a + 400 for a in (0, 0, 0, 0)]),
    ]
    for faults, deadlines in cases:
        pair = []
        for engine in ENGINES:
            sim = Simulator(prog, mesh, engine=engine, faults=faults)
            _, stats = sim.run(images, deadlines=deadlines, stalls=True)
            stats.stalls.check()
            pair.append(stats.stalls)
        assert pair[0] == pair[1]
    # the faulted run attributed dead and failed cycles somewhere
    assert pair[0].total(DEAD) > 0
    assert pair[0].total(FAILED) > 0
    assert pair[0].total(LINK_DELAY) > 0


# ----------------------------------------------------------- trace contract
def test_trace_byte_identical_across_runs_and_engines(lenet, tmp_path):
    _, chip, prog = lenet
    images = _images(3)
    blobs = {}
    for engine in ENGINES:
        paths = []
        for rep in range(2):
            tr = TraceRecorder()
            sim = Simulator(prog, chip, engine=engine)
            _, stats = sim.run(images, trace=tr)
            p = tmp_path / f"{engine}{rep}.json"
            tr.write(str(p), stats.cycles - 1, sim.stage_of_core())
            paths.append(p.read_bytes())
        assert paths[0] == paths[1], f"{engine}: same-seed bytes differ"
        blobs[engine] = paths[0]
    assert blobs["reference"] == blobs["event"]
    obj = json.loads(blobs["event"])
    assert obj["metadata"]["clock"] == "simulated-cycles"
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert phases == {"M", "X"}


def test_trace_viewer_roundtrip(lenet, tmp_path):
    repo = Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "trace_viewer", repo / "tools" / "trace_viewer.py")
    tv = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(tv)

    _, chip, prog = lenet
    tr = TraceRecorder()
    sim = Simulator(prog, chip)
    _, stats = sim.run(_images(2), trace=tr)
    p = tmp_path / "t.json"
    tr.write(str(p), stats.cycles - 1, sim.stage_of_core())
    obj = tv.load(str(p))
    assert tv.validate(obj) == []
    assert "busiest tracks" in tv.summarize(obj)
    out = tmp_path / "canon.json"
    tv.export(obj, str(out))
    assert out.read_bytes() == p.read_bytes()   # writer is already canonical


# ----------------------------------------------------------- critical path
def test_critical_path_matches_static_plan():
    g = build_lenet_like()
    chip = make_chip(8, "all_to_all", dma_pixels_per_cycle=4)
    prog = compile_model(g, chip)
    sim = Simulator(prog, chip)
    _, stats = sim.run(_images(4), stalls=True)
    cp = critical_path(stats)
    assert cp.kind == "stage"
    assert cp.name == static_bottleneck(prog.pgraph,
                                        chip.dma_pixels_per_cycle)
    assert 0.0 < cp.utilization <= 1.0
    assert cp.ranking[0][2] >= cp.ranking[-1][2]
    assert "rank" in cp.table()


def test_critical_path_matches_static_plan_tiny_xfmr():
    # tiny_xfmr is a balanced pipeline: several stages (and, at dma=1,
    # the GCU stream) tie for max busy.  The cross-check contract under
    # ties: the static pick must be *a* binding resource — its measured
    # busy equals the dynamic maximum.
    from repro.core import build_tiny_transformer
    g = build_tiny_transformer()
    chip = make_chip(12, "all_to_all", dma_pixels_per_cycle=1)
    prog = compile_model(g, chip)
    sim = Simulator(prog, chip)
    _, stats = sim.run(_images(6, shape=(8, 4, 1)), stalls=True)
    cp = critical_path(stats)
    static = static_bottleneck(prog.pgraph, chip.dma_pixels_per_cycle)
    busy_of = {name: busy for _, name, busy in cp.ranking}
    assert busy_of[static] == cp.busy, (static, cp.ranking)


def test_critical_path_requires_stalls(lenet):
    _, chip, prog = lenet
    _, stats = Simulator(prog, chip).run(_images(1))
    with pytest.raises(ValueError, match="stalls=True"):
        critical_path(stats)


# ------------------------------------------------------- serving telemetry
def test_serve_metrics_report_and_trace(lenet):
    g, chip, _ = lenet
    prog = compile_model(g, chip)
    srv = CmServer(prog, chip)
    images = _images(4)
    tr = TraceRecorder()
    rep = srv.serve_images(images, arrivals=[0, 30, 60, 90])
    # metrics agree with the report
    snap = rep.metrics.snapshot()
    assert snap["counters"]["requests_total"] == 4
    assert snap["counters"]["requests_succeeded"] == len(rep.successes())
    assert snap["histograms"]["latency_cycles"]["count"] == 4
    assert snap["gauges"]["makespan_cycles"] == rep.makespan
    assert srv.metrics is rep.metrics
    # well-formed report exports
    obj = json.loads(rep.to_json())
    assert obj["summary"]["requests"] == 4
    assert len(obj["requests"]) == 4
    assert obj["metrics"] == snap
    assert "counters:" in rep.to_table()
    # traced serve: request lifecycle spans labelled by rid
    for r in rep.requests:
        r.done = False
    rep2 = srv.serve(list(rep.requests), stalls=True, trace=tr)
    assert [r.completion for r in rep2.requests] \
        == [r.completion for r in rep.requests]
    names = {e["name"] for e in
             tr.finalize(rep2.stats.cycles - 1)["traceEvents"]}
    assert "service" in names
    assert rep2.stats.stalls is not None       # single epoch: preserved
    rep2.stats.stalls.check()


def test_serve_fault_recovery_trace_events(lenet):
    g, chip, _ = lenet
    prog = compile_model(g, chip)
    victim = sorted(prog.cores)[1]
    faults = FaultSchedule(core_faults=(CoreFault(victim, cycle=60),))
    srv = CmServer(prog, chip, faults=faults, deadline=300,
                   retry=RetryPolicy(max_retries=2, backoff_cycles=16))
    tr = TraceRecorder()
    rep = srv.serve_images(_images(3), arrivals=[0, 40, 80])
    # re-serve traced (serve resets verdicts, so reports must agree)
    srv2 = CmServer(prog, chip, faults=faults, deadline=300,
                    retry=RetryPolicy(max_retries=2, backoff_cycles=16))
    rep2 = srv2.serve(list(rep.requests), trace=tr)
    assert [r.completion for r in rep2.requests] \
        == [r.completion for r in rep.requests]
    assert rep2.n_retries > 0 and rep2.remap_events
    names = {e["name"] for e in
             tr.finalize(rep2.stats.cycles - 1)["traceEvents"]}
    assert {"remap-ok", "retry-wait", "service", "deadline-failed"} <= names
    snap = rep2.metrics.snapshot()
    assert snap["counters"]["retries_total"] == rep2.n_retries
    assert snap["counters"]["remaps_ok_total"] == 1
    assert snap["counters"]["reprogram_cycles_total"] \
        == rep2.reprogram_cycles
