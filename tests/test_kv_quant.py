"""int8 KV cache (§Perf pair B): quantizer round-trip bound, and end-to-end
decode parity — an int8-cached decode must track the fp-cached decode within
quantization tolerance, step after step."""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # gated: optional test dep
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_config
from repro.models import build_model
from repro.models import layers as L


@settings(max_examples=30, deadline=None)
@given(b=st.integers(1, 4), s=st.integers(1, 8), h=st.integers(1, 4),
       d=st.sampled_from([4, 16, 64]), seed=st.integers(0, 2**31 - 1))
def test_kv_quantize_roundtrip(b, s, h, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, s, h, d)) * 3, jnp.float32)
    q, sc = L.kv_quantize(x)
    assert q.dtype == jnp.int8 and sc.shape == (b, s, h, 1)
    back = L.kv_dequantize(q, sc, jnp.float32)
    # per-(pos, head) symmetric int8: |err| <= absmax/254 elementwise
    bound = np.abs(np.asarray(x)).max(axis=-1, keepdims=True) / 254 + 1e-7
    assert (np.abs(np.asarray(back - x)) <= bound + 1e-6).all()


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "llama3.2-3b"])
def test_decode_parity_int8_vs_fp(arch):
    """Prefill + 4 decode steps; int8-cached logits track fp logits."""
    cfg_fp = smoke_config(arch)
    cfg_q = dataclasses.replace(cfg_fp, kv_dtype="int8")
    rng = np.random.default_rng(0)
    b, s = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg_fp.vocab_size, (b, s)),
                         jnp.int32)

    model_fp = build_model(cfg_fp)
    model_q = build_model(cfg_q)
    params = model_fp.init(jax.random.key(0))     # same params both modes

    logits_fp, cache_fp = model_fp.prefill(params, {"tokens": tokens}, 32)
    logits_q, cache_q = model_q.prefill(params, {"tokens": tokens}, 32)
    # prefill last-token logits must already agree closely
    np.testing.assert_allclose(np.asarray(logits_fp), np.asarray(logits_q),
                               atol=0.08, rtol=0.05)

    nxt = jnp.argmax(logits_fp, -1).astype(jnp.int32)
    for _ in range(4):
        logits_fp, cache_fp = model_fp.decode_step(params, cache_fp, nxt)
        logits_q, cache_q = model_q.decode_step(params, cache_q, nxt)
        np.testing.assert_allclose(
            np.asarray(logits_fp), np.asarray(logits_q),
            atol=0.15, rtol=0.08)
        nxt = jnp.argmax(logits_fp, -1).astype(jnp.int32)

    # the int8 cache really is int8 (the memory win is real)
    kv_leaves = [l for l in jax.tree.leaves(cache_q["layers"])
                 if l.dtype == jnp.int8]
    assert kv_leaves, "no int8 leaves in quantized cache"
