"""Paper §2/§3.4: pipelined multi-core simulation ≡ reference executor.

The simulator's ``check_raw=True`` oracle independently asserts that every
SRAM location read was previously written — a generated-LCU bug trips it.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (DeadlockError, Simulator, build_fig2_graph,
                        build_lenet_like, build_resnet_block_chain,
                        compile_model, execute_reference, make_chip,
                        serialize_config)
from repro.kernels import ref as kref


def _images(shape, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=shape).astype(np.float32) for _ in range(n)]


def _compare(graph, chip, images, schedule="pipelined", mxv_fn=None):
    prog = compile_model(graph, chip)
    sim = Simulator(prog, chip, mxv_fn=mxv_fn, check_raw=True)
    outs, stats = sim.run(images, schedule=schedule)
    for img, out in zip(images, outs):
        want = execute_reference(graph, {"x": img}, mxv_fn=mxv_fn)
        for k in want:
            np.testing.assert_allclose(out[k], want[k], rtol=1e-5, atol=1e-5)
    return stats


def test_fig2_pipelined_equivalence():
    g = build_fig2_graph()
    _compare(g, make_chip(4, "all_to_all"), _images((4, 8, 8), 3))


def test_lenet_pipelined_equivalence():
    g = build_lenet_like()
    _compare(g, make_chip(8, "banded"), _images((1, 12, 12), 2))


def test_resnet_chain_pipelined_equivalence():
    g = build_resnet_block_chain(n_blocks=3)
    _compare(g, make_chip(10, "banded"), _images((4, 8, 8), 3))


def test_sequential_schedule_equivalence():
    g = build_resnet_block_chain(n_blocks=2)
    _compare(g, make_chip(8, "all_to_all"), _images((4, 8, 8), 2),
             schedule="sequential")


def test_pipelining_overlaps_execution():
    """The paper's raison d'être: inter-layer pipelining beats sequential."""
    g = build_resnet_block_chain(n_blocks=3)
    chip = make_chip(10, "banded")
    imgs = _images((4, 8, 8), 4)
    pipe = _compare(g, chip, imgs, "pipelined")
    seq = _compare(g, chip, imgs, "sequential")
    assert pipe.cycles < seq.cycles / 2, (pipe.cycles, seq.cycles)
    assert pipe.mean_utilization() > seq.mean_utilization()


def test_quantized_crossbar_matches_reference():
    """int8 'analog programming' (paper §3.5 / [41]): sim ≡ ref bit-for-bit
    when both use the same quantized MxV."""
    g = build_lenet_like()
    chip = make_chip(8, "all_to_all")

    def quant_mxv(m, v):
        wq, sc = kref.quantize_crossbar(np.asarray(m, np.float32))
        return np.asarray(kref.crossbar_mxv_ref(
            np.asarray(v, np.float32)[None], np.asarray(wq),
            np.asarray(sc))[0])

    _compare(g, chip, _images((1, 12, 12), 2), mxv_fn=quant_mxv)


def test_multi_image_streaming():
    """GCU streams several images; pipeline drains in order."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    stats = _compare(g, chip, _images((4, 8, 8), 6))
    assert stats.messages > 0 and stats.bytes_sent > 0


def test_serialized_config_roundtrip():
    """Paper §3: configs are bundled + serialized to init the accelerator."""
    import json
    g = build_fig2_graph()
    prog = compile_model(g, make_chip(4, "all_to_all"))
    blob = serialize_config(prog)
    cfg = json.loads(blob)
    assert set(cfg) == {"cores", "gcu", "mapping"}
    for core in cfg["cores"].values():
        for lc in core["lcu"].values():
            assert "def s_eval(" in lc["s_code"]  # generated LCU code ships


def test_deadlock_detection():
    """A core whose LCU never unblocks must be reported, not hang."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    prog = compile_model(g, chip)
    # Sabotage: make core 0's frontier never advance by replacing its LCU
    # evaluator with one that never returns a bound.
    sim = Simulator(prog, chip, check_raw=False)
    first_core = min(prog.cores)
    for lc in prog.cores[first_core].lcu.values():
        lc.gen_src = "def s_eval(*a):\n    return None\n"
        lc.dep.D_lexmin = (0,) * lc.dep.reader_ndim  # keep it bounded
    with pytest.raises(DeadlockError):
        sim.run(_images((4, 8, 8), 1), max_cycles=2000)
