"""Paper §2/§3.4: pipelined multi-core simulation ≡ reference executor.

The simulator's ``check_raw=True`` oracle independently asserts that every
SRAM location read was previously written — a generated-LCU bug trips it.
The event-driven engine (default) is additionally held to bit-identical
outputs and identical cycle/message statistics against ``engine="reference"``.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (DeadlockError, Simulator, build_fig2_graph,
                        build_lenet_like, build_resnet_block_chain,
                        compile_model, execute_reference, make_chip,
                        serialize_config)
from repro.core import poly
from repro.kernels import ref as kref


def _images(shape, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=shape).astype(np.float32) for _ in range(n)]


def _compare(graph, chip, images, schedule="pipelined", mxv_fn=None):
    prog = compile_model(graph, chip)
    sim = Simulator(prog, chip, mxv_fn=mxv_fn, check_raw=True)
    outs, stats = sim.run(images, schedule=schedule)
    for img, out in zip(images, outs):
        want = execute_reference(graph, {"x": img}, mxv_fn=mxv_fn)
        for k in want:
            np.testing.assert_allclose(out[k], want[k], rtol=1e-5, atol=1e-5)
    return stats


def test_fig2_pipelined_equivalence():
    g = build_fig2_graph()
    _compare(g, make_chip(4, "all_to_all"), _images((4, 8, 8), 3))


def test_lenet_pipelined_equivalence():
    g = build_lenet_like()
    _compare(g, make_chip(8, "banded"), _images((1, 12, 12), 2))


def test_resnet_chain_pipelined_equivalence():
    g = build_resnet_block_chain(n_blocks=3)
    _compare(g, make_chip(10, "banded"), _images((4, 8, 8), 3))


def test_sequential_schedule_equivalence():
    g = build_resnet_block_chain(n_blocks=2)
    _compare(g, make_chip(8, "all_to_all"), _images((4, 8, 8), 2),
             schedule="sequential")


def test_pipelining_overlaps_execution():
    """The paper's raison d'être: inter-layer pipelining beats sequential."""
    g = build_resnet_block_chain(n_blocks=3)
    chip = make_chip(10, "banded")
    imgs = _images((4, 8, 8), 4)
    pipe = _compare(g, chip, imgs, "pipelined")
    seq = _compare(g, chip, imgs, "sequential")
    assert pipe.cycles < seq.cycles / 2, (pipe.cycles, seq.cycles)
    assert pipe.mean_utilization() > seq.mean_utilization()


def test_quantized_crossbar_matches_reference():
    """int8 'analog programming' (paper §3.5 / [41]): sim ≡ ref bit-for-bit
    when both use the same quantized MxV."""
    g = build_lenet_like()
    chip = make_chip(8, "all_to_all")

    def quant_mxv(m, v):
        wq, sc = kref.quantize_crossbar(np.asarray(m, np.float32))
        return np.asarray(kref.crossbar_mxv_ref(
            np.asarray(v, np.float32)[None], np.asarray(wq),
            np.asarray(sc))[0])

    _compare(g, chip, _images((1, 12, 12), 2), mxv_fn=quant_mxv)


def test_multi_image_streaming():
    """GCU streams several images; pipeline drains in order."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    stats = _compare(g, chip, _images((4, 8, 8), 6))
    assert stats.messages > 0 and stats.bytes_sent > 0


def test_serialized_config_roundtrip():
    """Paper §3: configs are bundled + serialized to init the accelerator."""
    import json
    g = build_fig2_graph()
    prog = compile_model(g, make_chip(4, "all_to_all"))
    blob = serialize_config(prog)
    cfg = json.loads(blob)
    assert set(cfg) == {"cores", "gcu", "mapping"}
    for core in cfg["cores"].values():
        for lc in core["lcu"].values():
            assert "def s_eval(" in lc["s_code"]  # generated LCU code ships


@pytest.mark.parametrize("engine", ["event", "reference"])
def test_deadlock_detection(engine):
    """A core whose LCU never unblocks must be reported, not hang."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    prog = compile_model(g, chip)
    # Sabotage: make core 0's frontier never advance by replacing its LCU
    # evaluator (reference engine) and its compiled frontier table (event
    # engine) with never-advancing variants.
    sim = Simulator(prog, chip, check_raw=False, engine=engine)
    first_core = min(prog.cores)
    for lc in prog.cores[first_core].lcu.values():
        lc.gen_src = "def s_eval(*a):\n    return None\n"
        lc.dep.D_lexmin = (0,) * lc.dep.reader_ndim  # keep it bounded
        lc.table = poly.FrontierTable(
            rank=np.full(lc.table.rank.shape, -1, np.int64),
            reader_bounds=lc.table.reader_bounds,
            d_lexmin_rank=0, d_lexmax_rank=lc.table.d_lexmax_rank)
    with pytest.raises(DeadlockError):
        sim.run(_images((4, 8, 8), 1), max_cycles=2000)


@pytest.mark.parametrize("engine", ["event", "reference"])
def test_max_cycles_budget_enforced(engine):
    """A run whose true completion exceeds max_cycles must raise in BOTH
    engines (the event engine detects completion ahead of time but still has
    to honor the cycle budget)."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    prog = compile_model(g, chip)
    imgs = _images((4, 8, 8), 1)
    _, stats = Simulator(prog, chip, engine=engine).run(imgs)
    true_cycles = stats.cycles          # 78 for this graph
    for budget in (true_cycles // 2, true_cycles - 1):
        with pytest.raises(DeadlockError):
            Simulator(prog, chip, engine=engine).run(imgs, max_cycles=budget)
    # exactly enough budget succeeds
    _, ok = Simulator(prog, chip, engine=engine).run(imgs,
                                                     max_cycles=true_cycles)
    assert ok.cycles == true_cycles


# ------------------------------------------------------- engine equivalence
@pytest.mark.parametrize("schedule", ["pipelined", "sequential"])
@pytest.mark.parametrize("case", ["lenet", "resnet_chain"])
def test_engine_equivalence(case, schedule):
    """Event engine ≡ reference engine: bit-identical outputs, identical
    cycle/message/byte accounting (the perf rewrite must not change any
    observable of the paper's §2 timing model)."""
    if case == "lenet":
        g, chip, shp = build_lenet_like(), make_chip(8, "banded"), (1, 12, 12)
    else:
        g, chip, shp = (build_resnet_block_chain(3), make_chip(10, "banded"),
                        (4, 8, 8))
    imgs = _images(shp, 3)
    prog = compile_model(g, chip)
    ref = Simulator(prog, chip, check_raw=True, engine="reference")
    ev = Simulator(prog, chip, check_raw=True, engine="event")
    o_ref, s_ref = ref.run(imgs, schedule=schedule)
    o_ev, s_ev = ev.run(imgs, schedule=schedule)
    for a, b in zip(o_ref, o_ev):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])   # bit-identical
    assert s_ev.cycles == s_ref.cycles
    assert s_ev.messages == s_ref.messages
    assert s_ev.bytes_sent == s_ref.bytes_sent
    assert dict(s_ev.busy) == dict(s_ref.busy)
    assert s_ev.first_busy == s_ref.first_busy
    assert s_ev.last_busy == s_ref.last_busy
    assert dict(s_ev.sram_high_water) == dict(s_ref.sram_high_water)


@pytest.mark.parametrize("schedule", ["pipelined", "sequential"])
def test_sram_high_water_replay_matches_reference(schedule):
    """The event engine replays end-of-cycle SRAM sampling from its buffer
    lifetime log; multi-image pipelining is the case where same-cycle
    create/retire overlaps used to over-report vs the reference's dense
    per-cycle sampling (old ROADMAP open item)."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    prog = compile_model(g, chip)
    imgs = _images((4, 8, 8), 6)
    _, s_ref = Simulator(prog, chip, engine="reference").run(
        imgs, schedule=schedule)
    _, s_ev = Simulator(prog, chip, engine="event").run(
        imgs, schedule=schedule)
    assert dict(s_ev.sram_high_water) == dict(s_ref.sram_high_water)
    # pipelining must actually overlap images for this to exercise anything
    if schedule == "pipelined":
        single = Simulator(prog, chip, engine="reference").run(
            imgs[:1])[1].sram_high_water
        assert any(s_ref.sram_high_water[c] > single[c]
                   for c in single), "no multi-image overlap exercised"


def test_event_engine_batched_mxv_hook():
    """The stacked-MxV hook (Pallas-style backend) stays numerically close
    to the per-iteration path and identical in timing."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    prog = compile_model(g, chip)
    imgs = _images((4, 8, 8), 2)
    base = Simulator(prog, chip, engine="event")
    hooked = Simulator(prog, chip, engine="event",
                       mxv_batch_fn=lambda m, V: (m @ V.T).T)
    o1, s1 = base.run(imgs)
    o2, s2 = hooked.run(imgs)
    for a, b in zip(o1, o2):
        for k in a:
            np.testing.assert_allclose(a[k], b[k], rtol=1e-5, atol=1e-5)
    assert (s1.cycles, s1.messages) == (s2.cycles, s2.messages)
