"""Batched compute plane (core/compute_plane.py): backend matrix.

Equivalence contract:
  * ``reference`` vs ``numpy`` plane: bit-identical outputs and identical
    cycle/message accounting on both engines and both schedules (einsum is
    batch-invariant, so stacking MxVs changes no output bit);
  * ``pallas`` plane (interpret mode): identical accounting; outputs within
    atol once the crossbar matrix is dequantized-int8 (matmul rounding only);
  * ``strict_float_order=False``: identical accounting, outputs within
    np.allclose tolerance (float adds in avg-pool paths reassociate).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (Simulator, build_fig2_graph, build_lenet_like,
                        build_resnet_block_chain, compile_model,
                        dequantize_int8, make_chip)
from repro.core.compute_plane import (NumpyPlane, PallasPlane,
                                      make_descriptor, quantize_matrix,
                                      resolve_plane)


def _images(shape, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=shape).astype(np.float32) for _ in range(n)]


def _case(name):
    if name == "lenet":       # conv/relu/maxpool/gemm
        return build_lenet_like(), make_chip(8, "banded"), (1, 12, 12)
    if name == "resnet":      # conv/relu/add (skip connections)
        return (build_resnet_block_chain(2), make_chip(8, "banded"),
                (4, 8, 8))
    raise KeyError(name)


def _run(prog, chip, images, schedule, engine="event", **kw):
    sim = Simulator(prog, chip, check_raw=True, engine=engine, **kw)
    return sim.run(images, schedule=schedule)


def _assert_stats_equal(a, b):
    assert a.cycles == b.cycles
    assert a.messages == b.messages
    assert a.bytes_sent == b.bytes_sent
    assert dict(a.busy) == dict(b.busy)
    assert dict(a.sram_high_water) == dict(b.sram_high_water)


# ------------------------------------------------------------ backend matrix
@pytest.mark.parametrize("schedule", ["pipelined", "sequential"])
@pytest.mark.parametrize("case", ["lenet", "resnet"])
def test_reference_vs_numpy_plane_bit_identical(case, schedule):
    """The batching oracle: stacking MxVs through the numpy plane must not
    change a single output bit vs the per-iteration reference loop, on
    either engine."""
    g, chip, shp = _case(case)
    prog = compile_model(g, chip)
    imgs = _images(shp, 3)
    runs = {
        (eng, plane): _run(prog, chip, imgs, schedule, engine=eng,
                           compute_plane=plane)
        for eng in ("event", "reference")
        for plane in ("numpy", "reference")
    }
    base_out, base_stats = runs[("event", "numpy")]
    for key, (outs, stats) in runs.items():
        for a, b in zip(base_out, outs):
            for v in a:
                np.testing.assert_array_equal(a[v], b[v], err_msg=str(key))
        _assert_stats_equal(base_stats, stats)


def test_einsum_batch_invariance_is_what_makes_it_work():
    """The property the numpy plane rests on, asserted directly: a stacked
    einsum row equals the single-row call bit-for-bit (BLAS gemm does NOT
    have this property — 1-row calls dispatch to gemv)."""
    rng = np.random.default_rng(0)
    plane = NumpyPlane()
    for m_, n_, b_ in [(4, 36, 7), (8, 72, 64), (10, 128, 17)]:
        desc = make_descriptor(rng.normal(size=(m_, n_)), "conv2d")
        V = rng.normal(size=(b_, n_)).astype(np.float32)
        Y = plane.mxv_batch(desc, V)
        for i in (0, b_ // 2, b_ - 1):
            np.testing.assert_array_equal(Y[i], plane.mxv_one(desc, V[i]))


# ------------------------------------------------------------- pallas plane
@pytest.mark.parametrize("schedule", ["pipelined", "sequential"])
def test_pallas_plane_interpret_equivalence(schedule):
    """With a dequantized-int8 crossbar matrix, the pallas plane (interpret
    mode on CPU) matches the numpy plane within matmul rounding: documented
    atol 2e-5 / rtol 1e-5.  Accounting must be identical — planes change
    value bits, never timing."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    prog = compile_model(g, chip, quantizer=dequantize_int8)
    imgs = _images((4, 8, 8), 2)
    o_np, s_np = _run(prog, chip, imgs, schedule, compute_plane="numpy")
    o_pl, s_pl = _run(prog, chip, imgs, schedule, compute_plane="pallas")
    for a, b in zip(o_np, o_pl):
        for v in a:
            np.testing.assert_allclose(a[v], b[v], rtol=1e-5, atol=2e-5)
    _assert_stats_equal(s_np, s_pl)


def test_pallas_int8_dac_plane():
    """The fully-int8 path (DAC-quantized activations): int8 quantization
    error dominates (~1% relative on this workload), timing identical."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    prog = compile_model(g, chip, quantizer=dequantize_int8)
    imgs = _images((4, 8, 8), 1)
    o_np, s_np = _run(prog, chip, imgs, "pipelined", compute_plane="numpy")
    o_dac, s_dac = _run(prog, chip, imgs, "pipelined",
                        compute_plane=PallasPlane(dac=True))
    for a, b in zip(o_np, o_dac):
        for v in a:
            scale = np.abs(a[v]).max()
            assert np.abs(a[v] - b[v]).max() < 0.05 * max(scale, 1.0)
    _assert_stats_equal(s_np, s_dac)


# ------------------------------------------------------- strict float order
def _avgpool_graph():
    """conv → relu → avgpool → conv → global_avgpool: both float-accumulating
    DPU reductions in one pipeline."""
    from repro.core import Graph
    rng = np.random.default_rng(3)
    g = Graph()
    x = g.add_input("x", (4, 8, 8))
    w1 = g.add_weight("w1", rng.normal(size=(4, 4, 3, 3), scale=0.3))
    w2 = g.add_weight("w2", rng.normal(size=(6, 4, 3, 3), scale=0.3))
    h = g.conv2d("conv1", x, w1, pad=1)
    h = g.relu("relu1", h)
    h = g.avgpool2d("pool1", h)
    h = g.conv2d("conv2", h, w2)
    out = g.global_avgpool("gap", h)
    g.mark_output(out)
    g.validate()
    return g


@pytest.mark.parametrize("schedule", ["pipelined", "sequential"])
def test_strict_float_order_flag(schedule):
    """strict=True (default) keeps the reference's per-iteration float
    accumulation order (bit-identical to the reference engine); strict=False
    reassociates avg-pool adds: same timing, np.allclose outputs."""
    g = _avgpool_graph()
    chip = make_chip(6, "banded")
    prog = compile_model(g, chip)
    imgs = _images((4, 8, 8), 3)
    o_ref, s_ref = _run(prog, chip, imgs, schedule, engine="reference")
    o_strict, s_strict = _run(prog, chip, imgs, schedule,
                              strict_float_order=True)
    o_fast, s_fast = _run(prog, chip, imgs, schedule,
                          strict_float_order=False)
    for a, b in zip(o_ref, o_strict):
        for v in a:
            np.testing.assert_array_equal(a[v], b[v])
    for a, b in zip(o_ref, o_fast):
        for v in a:
            np.testing.assert_allclose(a[v], b[v], rtol=1e-5, atol=1e-5)
    _assert_stats_equal(s_ref, s_strict)
    _assert_stats_equal(s_ref, s_fast)


# --------------------------------------------------------------- descriptors
def test_lowering_attaches_compute_descriptors():
    g = build_lenet_like()
    chip = make_chip(8, "banded")
    prog = compile_model(g, chip)
    seen = 0
    for cfg in prog.cores.values():
        if cfg.xbar_node is None:
            assert cfg.compute is None
            continue
        seen += 1
        d = cfg.compute
        assert d is not None and d.op == cfg.xbar_node.op
        assert d.wq.dtype == np.int8 and d.wq.shape == cfg.xbar_matrix.shape
        wq, sc = quantize_matrix(cfg.xbar_matrix)
        np.testing.assert_array_equal(d.wq, wq)
        np.testing.assert_array_equal(d.wscale, sc)
        # int8 round-trip stays within one quantization step per element
        deq = d.wq.astype(np.float32) * d.wscale[:, None]
        assert np.abs(deq - d.matrix).max() <= (d.wscale.max() / 2) + 1e-7
    assert seen >= 3


# ---------------------------------------------------------------- resolution
def test_plane_resolution_rules():
    assert resolve_plane("auto").name == "numpy"
    assert resolve_plane("auto", mxv_fn=lambda m, v: m @ v).name == "reference"
    assert resolve_plane("pallas").name == "pallas"
    inst = NumpyPlane()
    assert resolve_plane(inst) is inst
    assert resolve_plane(
        "numpy", mxv_batch_fn=lambda m, V: (m @ V.T).T).name == "custom"
    with pytest.raises(ValueError):
        resolve_plane("numpy", mxv_fn=lambda m, v: m @ v)
    with pytest.raises(ValueError):
        resolve_plane("no_such_backend")


def test_custom_mxv_fn_uses_reference_loop():
    """A custom mxv_fn (e.g. quantized) must flow through both engines
    unchanged — auto-resolution falls back to the per-iteration loop."""
    g = build_fig2_graph()
    chip = make_chip(4, "all_to_all")
    prog = compile_model(g, chip)
    imgs = _images((4, 8, 8), 2)
    calls = {"n": 0}

    def noisy(m, v):
        calls["n"] += 1
        return (m @ v) * np.float32(1.0)

    sim = Simulator(prog, chip, mxv_fn=noisy)
    assert sim.plane.name == "reference"
    o_ev, _ = sim.run(imgs)
    assert calls["n"] > 0
    o_ref, _ = Simulator(prog, chip, mxv_fn=noisy,
                         engine="reference").run(imgs)
    for a, b in zip(o_ev, o_ref):
        for v in a:
            np.testing.assert_array_equal(a[v], b[v])
