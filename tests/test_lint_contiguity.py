"""tools/lint_contiguity.py — the contiguity convention stays enforced."""

import importlib.util
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "lint_contiguity", REPO / "tools" / "lint_contiguity.py")
lint = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(lint)


def _msgs(src):
    return [m for _, _, m in lint.lint_source(src, "<test>")]


def test_repo_is_clean():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "lint_contiguity.py")],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_flags_transposed_einsum_operand():
    assert _msgs("import numpy as np\ny = np.einsum('ij,jk->ik', a.T, b)\n")


def test_flags_sliced_plane_operand():
    assert _msgs("y = mxv_one(desc, v[:, 0])\n")
    assert _msgs("y = mxv_batch(desc, V.transpose(1, 0))\n")
    assert _msgs("y = dyn_mxv_one(m, v.reshape(-1))\n")


def test_wrapped_and_benign_operands_pass():
    ok = (
        "import numpy as np\n"
        "y = np.einsum('ij,jk->ik', np.ascontiguousarray(a.T), b)\n"
        "z = mxv_one(desc, np.ascontiguousarray(v[:, 0]))\n"
        "w = mxv_batch(desc, V)\n"
        "u = dyn_mxv_one(m, p['w'])\n"      # dict lookup, not a view
        "t = dyn_mxv_batch(m, V[i])\n"      # leading-axis row: contiguous
    )
    assert not _msgs(ok)


def test_flags_einsum_out_keyword():
    assert _msgs("np.einsum('ij->ji', a, out=buf[:, 0])\n")
