"""Example: the design-space autotuner end to end (ISSUE 10).

  1. run a small seeded search over replication factors for the lenet
     pipeline — the staged funnel compiles every candidate, pre-filters
     with the static verifier (free discards), ranks the survivors by
     static image interval, and simulates only the shortlist, steering
     each round at the measured critical path;
  2. print the search trajectory: where each candidate left the funnel;
  3. load the *committed* tuned config (``configs/tuned/lenet.json``)
     through ``compile_model(..., tune="lenet")`` and confirm the
     recorded score reproduces on the event engine.

Everything is seeded — re-running this script gives identical output.

Run: PYTHONPATH=src python examples/autotuned_pipeline.py
"""

import numpy as np

from repro.core import Simulator, compile_model
from repro.tune import SearchSpace, TuneWorkload, ZOO, autotune, load_tuned


def main():
    entry = ZOO["lenet"]
    graph, chip = entry.build(), entry.chip()

    # 1. a fresh (small) search
    result = autotune(graph, chip, TuneWorkload(n_images=4), budget=12,
                      seed=0, space=SearchSpace(max_repl_k=16, batch=6,
                                                shortlist=2),
                      label="lenet")

    # 2. the trajectory: the funnel in action
    print(f"search: {result.counts['candidates']} candidates -> "
          f"{result.n_simulated} simulated "
          f"(discarded free: {result.counts['compile-error']} compile, "
          f"{result.counts['prefilter-discard']} prefilter, "
          f"{result.counts['ranked-out']} ranked out)")
    for t in result.trials:
        score = f"{t.cycles} cycles" if t.cycles is not None else t.stage
        print(f"  [{t.index:2d}] {t.config.key():<34} {score:<18} "
              f"({t.provenance})")
    print(f"best: {result.best.key()} = {result.best_cycles} cycles "
          f"(heuristic baseline {result.baseline.key()} = "
          f"{result.baseline_cycles})")

    # 3. the committed artifact, through the compiler front door
    art = load_tuned("lenet")
    prog = compile_model(entry.build(), chip, tune="lenet")
    rng = np.random.default_rng(entry.workload.seed)
    shape = tuple(int(x) for x in graph.values[graph.inputs[0]].shape)
    images = [rng.normal(size=shape).astype(np.float32)
              for _ in range(entry.workload.n_images)]
    _, stats = Simulator(prog, chip, check_raw=False).run(
        images, schedule=entry.workload.schedule)
    print(f"committed configs/tuned/lenet.json: recorded {art['cycles']} "
          f"cycles, re-simulated {stats.cycles} "
          f"({'match' if stats.cycles == art['cycles'] else 'DRIFT'})")
    assert stats.cycles == art["cycles"]


if __name__ == "__main__":
    main()
