"""Quickstart: the paper end-to-end in one page.

Compile a CNN with cmnnc (partition -> Z3 map -> polyhedral lowering),
simulate pipelined execution on the CM accelerator, and check the result
against the reference executor — with int8 "analog" crossbars.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (Simulator, build_resnet_block_chain, compile_model,
                        execute_reference, make_chip, serialize_config)
from repro.kernels import ref as kref


def quantized_mxv(m, v):
    """The crossbar model: int8 weights with per-row scales (paper §3.5)."""
    wq, sc = kref.quantize_crossbar(np.asarray(m, np.float32))
    return np.asarray(kref.crossbar_mxv_ref(
        np.asarray(v, np.float32)[None], np.asarray(wq), np.asarray(sc))[0])


def main():
    # 1. an NN dataflow graph (two residual blocks, paper Fig. 2 pattern)
    graph = build_resnet_block_chain(n_blocks=2, c=4, img=8)
    print(f"graph: {len(graph.nodes)} nodes, "
          f"{sum(1 for n in graph.nodes if n.op == 'conv2d')} convolutions")

    # 2. a CM accelerator: 8 cores, banded interconnect (5-prism stand-in)
    chip = make_chip(8, "banded", width=256, sram_bytes=256 * 1024)

    # 3. compile: partition (§3.1) -> Z3 mapping (§3.1) -> lowering (§3.2)
    #    with Appendix-A polyhedral LCU state machines
    prog = compile_model(graph, chip)
    print(f"partitions -> cores: {prog.mapping}")
    core0 = prog.cores[min(prog.cores)]
    print("one generated LCU evaluator:")
    print("\n".join("   " + ln for ln in
                    next(iter(core0.lcu.values())).gen_src.splitlines()[:6]))

    # 4. the serialized configuration bundle that initializes the chip
    blob = serialize_config(prog)
    print(f"serialized config: {len(blob)} bytes")

    # 5. simulate pipelined inference on a stream of images
    rng = np.random.default_rng(0)
    images = [rng.normal(size=(4, 8, 8)).astype(np.float32)
              for _ in range(4)]
    sim = Simulator(prog, chip, mxv_fn=quantized_mxv, check_raw=True)
    outs, stats = sim.run(images, schedule="pipelined")
    print(f"pipelined: {stats.cycles} cycles, "
          f"mean core utilization {stats.mean_utilization():.2f}")

    _, seq = sim.run(images, schedule="sequential")
    print(f"sequential: {seq.cycles} cycles "
          f"(pipeline speedup {seq.cycles / stats.cycles:.2f}x)")

    # 6. verify against the reference executor (same quantized crossbars)
    for img, out in zip(images, outs):
        want = execute_reference(graph, {"x": img}, mxv_fn=quantized_mxv)
        for k in want:
            np.testing.assert_allclose(out[k], want[k], rtol=1e-5, atol=1e-5)
    print("all outputs match the reference executor — OK")


if __name__ == "__main__":
    main()
