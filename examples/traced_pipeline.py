"""Example: observing a pipelined run — stalls, critical path, Perfetto.

ISSUE 9 walkthrough of the observability layer on the lenet pipeline:

  1. run with ``stalls=True`` — every idle core-cycle is attributed to a
     closed taxonomy (dep-wait on a named producer, gcu-starved,
     link-delay, drained, ...) and the per-core accounting identity
     ``busy + sum(stalls) == run cycles`` is checked;
  2. ``critical_path`` names the binding resource of the run and is
     cross-checked against the partitioner's *static* bottleneck pick;
  3. the same run re-executed with a ``TraceRecorder`` writes a
     Chrome-trace/Perfetto JSON (open in https://ui.perfetto.dev — the
     timestamps are simulated cycles) that is byte-identical across
     engines and repeat runs.

Run: PYTHONPATH=src python examples/traced_pipeline.py [--out DIR]
"""

import argparse
import pathlib

import numpy as np

from repro.core import Simulator, build_lenet_like, compile_model, make_chip
from repro.obs import TraceRecorder, critical_path, static_bottleneck


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=".",
                    help="directory for the trace JSON")
    args = ap.parse_args()

    graph = build_lenet_like()
    chip = make_chip(8, "all_to_all", dma_pixels_per_cycle=4)
    prog = compile_model(graph, chip)
    rng = np.random.default_rng(0)
    images = [rng.normal(size=(1, 12, 12)).astype(np.float32)
              for _ in range(4)]

    # 1. stall attribution (reference engine = the per-cycle oracle;
    #    the event engine reconstructs the identical breakdown)
    sim = Simulator(prog, chip, engine="reference")
    _, stats = sim.run(images, stalls=True)
    stats.stalls.check()              # busy + sum(stalls) == run cycles
    print("=== stall attribution (per stage) ===")
    print(stats.stalls.table())

    sim_ev = Simulator(prog, chip, engine="event")
    _, stats_ev = sim_ev.run(images, stalls=True)
    assert stats_ev.stalls == stats.stalls
    print("\nevent-engine breakdown bit-equal to the reference oracle: True")

    # 2. dynamic critical path vs the partitioner's static pick
    cp = critical_path(stats)
    print("\n=== critical path ===")
    print(cp.table())
    static = static_bottleneck(prog.pgraph, chip.dma_pixels_per_cycle)
    print(f"dynamic bottleneck: {cp.name}  |  static plan target: {static}")

    # 3. Perfetto trace — byte-identical for same-seed runs
    trace = TraceRecorder()
    _, st = sim_ev.run(images, trace=trace)
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    out = out_dir / "lenet_pipeline.trace.json"
    trace.write(str(out), st.cycles - 1, sim_ev.stage_of_core())
    print(f"\nwrote {out} ({out.stat().st_size} bytes) — "
          "open in ui.perfetto.dev (timestamps are cycles)")


if __name__ == "__main__":
    main()
