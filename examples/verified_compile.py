"""Example: statically verify a compiled CM program before simulating it.

The static verifier (``repro.analysis``) proves three families of
properties over a fully lowered/mapped program, without running a single
simulated cycle:

  1. dependency soundness — every compiled frontier automaton is checked
     against the Appendix-A access relations: no read is ever admitted
     before its writer, replica residues partition each writer domain;
  2. deadlock freedom — the stage-level wait-for graph is acyclic and
     every gate lifts by the end of its producer's stream;
  3. resource bounds — a per-core SRAM high-water upper bound and a
     per-link offered-load estimate.

Part 1 verifies a clean pipeline and prints the report.  Part 2 corrupts
one compiled frontier table the way a real compiler bug would (saturating
its rank entries, so the gate opens after the first write) and shows the
verifier naming the race statically.  Part 3 shows the one-argument
integration: ``compile_model(..., analyze=True)``.

Run: PYTHONPATH=src python examples/verified_compile.py
"""

import dataclasses

from repro.analysis import verify_program
from repro.core import (CompileValidationError, build_lenet_like, compile_model,
                        make_chip)


def main():
    chip = make_chip(8, "banded")
    g = build_lenet_like()

    # ---- part 1: a clean compile verifies with zero diagnostics
    prog = compile_model(g, chip)
    report = verify_program(prog, chip)
    print("clean program:", report.summary())
    print("  deps checked:           ", report.metrics["deps_checked"])
    print("  write events replayed:  ", report.metrics["write_events_replayed"])
    print("  wait-for edges (stages):", report.metrics["wait_edges"],
          f"({report.metrics['wait_stages']} stages, acyclic)")
    worst = max(report.metrics["sram_bound_bytes"].items(),
                key=lambda kv: kv[1])
    print(f"  SRAM high-water bound:   core {worst[0]}: {worst[1]}B "
          f"of {chip.core.sram_bytes}B")
    assert report.ok

    # ---- part 2: corrupt one frontier table -> the race is named, not run
    prog = compile_model(g, chip)
    dep = next(d for cfg in prog.cores.values()
               for lc in cfg.lcu.values() for d in lc.deps
               if d.table is not None and not d.table.never_constrains)
    rank = dep.table.rank.copy()
    rank[rank >= 0] = dep.table.d_lexmax_rank   # "everything ready at once"
    dep.table = dataclasses.replace(dep.table, rank=rank)

    report = verify_program(prog, chip)
    print("\ncorrupted table:", report.summary())
    for d in report.errors()[:3]:
        print("  ", d)
    assert not report.ok
    assert "frontier-unsound" in report.checks()

    # ---- part 3: the compile-time guard raises on the same corruption
    ok = compile_model(g, chip, analyze=True)
    print("\ncompile_model(analyze=True) on the clean graph: ok,",
          len(ok.cores), "cores")
    try:
        report.raise_if_errors(CompileValidationError)
    except CompileValidationError as e:
        print("raise_if_errors ->", str(e)[:72], "...")


if __name__ == "__main__":
    main()
