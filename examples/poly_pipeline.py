"""The paper's dependency compiler driving TPU pipeline parallelism.

Derives pipeline schedules from the Appendix-A ``S`` automata for all three
edge kinds (pointwise / causal / full), prints the schedule tables, then
executes a 4-stage pipeline under shard_map + ppermute and checks it against
the sequential reference.

Run:  PYTHONPATH=src python examples/poly_pipeline.py
(forces 4 host devices; run as its own process)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.core import pipeline  # noqa: E402


def show(kinds, n_items):
    sched = pipeline.derive_schedule(kinds, n_items)
    print(f"edges={kinds} items={n_items} -> makespan {sched.n_ticks} ticks,"
          f" utilization {sched.utilization():.2f}")
    for s, row in enumerate(sched.table):
        cells = " ".join(f"{v:2d}" if v >= 0 else " ." for v in row)
        print(f"  stage{s}: {cells}")


def main():
    print("== schedules derived from the Appendix-A automata ==")
    show(["pointwise"] * 3, 8)      # classic 1-deep pipeline (skew 1/stage)
    show(["causal"] * 3, 8)         # causal attention chunks: same skew
    show(["full", "pointwise"], 6)  # encoder edge degenerates to barrier

    print("\n== execution on a 4-device stage mesh ==")
    mesh = jax.make_mesh((4,), ("stage",))
    n_stages, n_items, dim = 4, 8, 64
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(n_stages, dim, dim)) / np.sqrt(dim),
                    jnp.float32)
    xs = jnp.asarray(rng.normal(size=(n_items, dim)), jnp.float32)
    fn = lambda p, x: jnp.tanh(x @ p)

    sched = pipeline.derive_schedule(["pointwise"] * (n_stages - 1), n_items)
    out = pipeline.pipeline_apply([fn] * n_stages, w, xs, sched, mesh)
    want = pipeline.sequential_apply([fn] * n_stages, w, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("pipelined output == sequential reference "
          f"(makespan {sched.n_ticks} ticks vs {n_stages * n_items} "
          "sequential) — OK")


if __name__ == "__main__":
    main()
