"""Example: continuous-batching inference server loop.

The paper's accelerator is configured once and streamed (§1-§2); here a
fixed-slot decode batch never drains — finished sequences free their slot
for queued requests mid-flight.

Run: PYTHONPATH=src python examples/continuous_serving.py
"""

import numpy as np

from repro.configs.base import smoke_config
from repro.serve.scheduler import ContinuousBatcher, Request


def main():
    cfg = smoke_config("qwen2-7b")
    rng = np.random.default_rng(0)

    engine = ContinuousBatcher(cfg, n_slots=4, max_len=64)

    # a bursty arrival pattern: 10 requests, ragged prompts/budgets
    reqs = []
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size,
                              (int(rng.integers(4, 14)),)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new=int(rng.integers(3, 8))))

    # submit in two bursts with engine ticks in between (requests queue
    # while slots are busy, then backfill as slots free)
    for r in reqs[:6]:
        engine.submit(r)
    for _ in range(4):
        engine.step()
    for r in reqs[6:]:
        engine.submit(r)
    engine.run_until_drained()

    for r in reqs:
        print(f"request {r.rid}: prompt_len={len(r.prompt)} "
              f"-> {len(r.out)} tokens {r.out}")
    print(f"engine steps: {engine.stats['steps']}, "
          f"prefills: {engine.stats['prefills']}, "
          f"slot utilization: {engine.utilization:.1%}")


if __name__ == "__main__":
    main()
