"""Example: request-level serving on the CM accelerator + the JAX batcher.

The paper's accelerator is configured once and *streamed* (§1-§2).  Part 1
drives the cycle-accurate serving runtime end-to-end: compile two models
onto disjoint core sets of one chip (weight-stationary co-residency),
submit a Poisson request stream against both tenants, drain, and print the
per-request latency table plus per-tenant percentiles.  Part 2 keeps the
JAX-side analogue: a fixed-slot continuous batcher whose freed slots
backfill mid-flight.

Run: PYTHONPATH=src python examples/continuous_serving.py
"""

import numpy as np

from repro.core import (build_fig2_graph, build_resnet_block_chain,
                        make_chip, place_tenants)
from repro.runtime import CmServer, poisson_arrivals, split_stats


def cm_serving():
    rng = np.random.default_rng(0)
    chip = make_chip(8, "banded")
    placement = place_tenants(
        [build_fig2_graph(), build_resnet_block_chain(2)], chip)
    print(f"tenant core ranges: {placement.core_ranges}")

    server = CmServer(placement, max_inflight=4)

    # open-loop Poisson traffic, requests alternating between the tenants
    n = 10
    arrivals = poisson_arrivals(n, rate=0.02, seed=7)
    for i, arrival in enumerate(arrivals):
        image = rng.normal(size=(4, 8, 8)).astype(np.float32)
        server.submit_image(image, arrival=int(arrival), tenant=i % 2)

    report = server.drain()            # submit -> drain -> latency table
    # to_table() = per-request table + the metrics-registry footer
    # (counters + cycle histograms CmServer populated during the serve)
    print(report.to_table())
    for tk in range(placement.n_tenants):
        print(f"tenant {tk}: p50={report.percentile(50, tenant=tk):.0f} "
              f"p99={report.percentile(99, tenant=tk):.0f} cycles")
    per = split_stats(report.stats, placement,
                      [r.tenant for r in report.requests])
    for tk, s in enumerate(per):
        print(f"tenant {tk}: busy cores={sorted(s.busy)} "
              f"mean util={s.mean_utilization():.1%}")
    # machine-readable form of the same report (summary + per-request
    # rows + metrics snapshot), e.g. for dashboards / regression diffs
    print(f"to_json(): {len(report.to_json())} bytes of JSON")


def jax_batcher():
    from repro.configs.base import smoke_config
    from repro.serve.scheduler import ContinuousBatcher, Request

    cfg = smoke_config("qwen2-7b")
    rng = np.random.default_rng(0)
    engine = ContinuousBatcher(cfg, n_slots=4, max_len=64)

    # a bursty arrival pattern: 10 requests, ragged prompts/budgets
    reqs = []
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size,
                              (int(rng.integers(4, 14)),)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new=int(rng.integers(3, 8))))

    # submit in two bursts with engine ticks in between (requests queue
    # while slots are busy, then backfill as slots free)
    for r in reqs[:6]:
        engine.submit(r)
    for _ in range(4):
        engine.step()
    for r in reqs[6:]:
        engine.submit(r)
    engine.run_until_drained()

    for r in reqs:
        print(f"request {r.rid}: prompt_len={len(r.prompt)} "
              f"-> {len(r.out)} tokens {r.out}")
    print(f"engine steps: {engine.stats['steps']}, "
          f"prefills: {engine.stats['prefills']}, "
          f"slot utilization: {engine.utilization:.1%}")


def main():
    print("=== CM serving runtime (cycle-accurate) ===")
    cm_serving()
    print("\n=== JAX continuous batcher ===")
    jax_batcher()


if __name__ == "__main__":
    main()
