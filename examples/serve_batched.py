"""Batched serving example: prefill a batch of prompts through a reduced
qwen2-7b config, then stream tokens with the jit'd decode step — the
"configure once, stream inputs" economics of the CM accelerator (paper §1)
applied to LM serving.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import numpy as np

from repro.configs.base import smoke_config
from repro.serve import ServeEngine


def main():
    cfg = smoke_config("qwen2-7b")
    engine = ServeEngine(cfg, max_len=96)
    rng = np.random.default_rng(0)

    batch, prompt_len, gen = 4, 32, 24
    prompts = rng.integers(0, cfg.vocab_size,
                           (batch, prompt_len)).astype(np.int32)
    out = engine.generate(prompts, gen)
    print(f"generated {out.shape} tokens:")
    for i, row in enumerate(out):
        print(f"  seq{i}: {row[:12].tolist()} ...")
    assert out.shape == (batch, gen)

    stats = engine.throughput_probe(batch, prompt_len, 8)
    print(f"prefill: {stats['prefill_s']*1e3:.1f} ms | "
          f"decode: {stats['decode_tok_per_s']:.1f} tok/s (host CPU)")


if __name__ == "__main__":
    main()
