"""Example: serving through hardware faults on the CM accelerator.

Analog CM hardware fails in characteristic ways: a core's crossbar stops
answering, an inter-chip link drops or degrades, conductances drift.  This
example injects a deterministic fault timeline (``repro.faults``) under a
live request stream and walks the full degradation story:

  1. a clean run — the goodput/latency baseline;
  2. the same stream with a core dying mid-run and *no* recovery: affected
     requests miss their deadline and fail at a detectable cycle (the
     simulation never hangs);
  3. recovery on: the server detects the failures at the deadline, re-solves
     the tenant's mapping with the dead core excluded (paying an explicit
     crossbar-reprogram penalty), re-admits the failed requests with
     exponential backoff, and every retried request completes with outputs
     bitwise equal to the clean run;
  4. crossbar value faults: the same program on a ``FaultyPlane`` (stuck
     cells + conductance drift) still serves, with deterministically
     perturbed outputs — degraded accuracy, not corruption.

Run: PYTHONPATH=src python examples/fault_tolerant_serving.py
"""

import json

import numpy as np

from repro.core import build_fig2_graph, make_chip, place_tenants
from repro.faults import CoreFault, FaultSchedule, FaultyPlane, RetryPolicy
from repro.runtime import CmServer


def main():
    rng = np.random.default_rng(0)
    chip = make_chip(8, "all_to_all")
    placement = place_tenants([build_fig2_graph()], chip)
    images = [rng.normal(size=(4, 8, 8)).astype(np.float32)
              for _ in range(6)]
    arrivals = [i * 40 for i in range(6)]

    # 1. clean baseline
    clean = CmServer(placement, chip).serve_images(images, arrivals=arrivals)
    print("=== clean run ===")
    print(clean.table())

    # kill one of the tenant's cores shortly into the run
    victim = sorted(placement.programs[0].cores)[1]
    faults = FaultSchedule(core_faults=(CoreFault(victim, cycle=60),))
    print(f"\ninjecting: core {victim} dies at cycle 60")

    # 2. failure detection only: requests stall on the dead core and are
    #    failed at their deadline instead of being simulated forever
    bare = CmServer(placement, chip, faults=faults, deadline=300)
    rep = bare.serve_images(images, arrivals=arrivals)
    print("\n=== no recovery: deadline failures ===")
    print(rep.table())

    # 3. full recovery: remap around the dead core + retry with backoff
    srv = CmServer(placement, chip, faults=faults, deadline=300,
                   retry=RetryPolicy(max_retries=2, backoff_cycles=16),
                   reprogram_cost_cycles=32)
    rep = srv.serve_images(images, arrivals=arrivals)
    print("\n=== recovery: remap + retry ===")
    # to_table() appends the metrics-registry footer: retry/remap counters
    # and the queue/service/latency cycle histograms of the serve
    print(rep.to_table())
    for ev in rep.remap_events:
        print(f"remap: tenant {ev['tenant']} at cycle {ev['cycle']}: "
              f"dead {ev['dead_cores']} -> cores {ev['new_cores']} "
              f"({ev['n_crossbars']} crossbars reprogrammed, "
              f"{ev['reprogram_cycles']} cycles)")
    ok = all(
        np.array_equal(r.output[k], clean.by_rid()[r.rid].output[k])
        for r in rep.requests if r.succeeded for k in r.output)
    print(f"recovered outputs bitwise equal to clean run: {ok}")
    summary = json.loads(rep.to_json())["summary"]
    print(f"to_json() summary: goodput={summary['goodput']} "
          f"retries={summary['n_retries']} remaps={summary['n_remaps']} "
          f"reprogram_cycles={summary['reprogram_cycles']}")

    # 4. crossbar value faults: stuck cells + drift, deterministic per seed
    noisy = CmServer(placement, chip,
                     compute_plane=FaultyPlane(stuck_fraction=0.05,
                                               drift_sigma=0.02, seed=7))
    rep = noisy.serve_images(images, arrivals=arrivals)
    r0, c0 = rep.by_rid()[0].output, clean.by_rid()[0].output
    err = max(float(np.max(np.abs(r0[k] - c0[k]))) for k in c0)
    print("\n=== stuck cells + drift (FaultyPlane) ===")
    print(f"all {len(rep.successes())} requests served; "
          f"max output deviation vs clean: {err:.4f} "
          "(degraded accuracy, deterministic, no timing change)")


if __name__ == "__main__":
    main()
