"""Transformer encoder block on the CM pipeline (ISSUE 5), in one page.

The paper's compiler targets CNNs; this quickstart runs an LLM-shaped
workload through the exact same flow.  Sequences ride the ``(C, H, W)``
layout with channels = features, H = tokens, W = 1, so

  * Q/K/V/O projections and the MLP gemms are 1x1 ``conv2d`` nodes —
    weight-stationary crossbar MxV, one token per iteration (unchanged);
  * layernorm/softmax are fused DPU ops (row-wise over the channel dim);
  * QKᵀ and attention·V are *dynamic* ``matmul`` ops: both operands are
    streamed activations, so nothing can be programmed into a crossbar —
    they lower to DPU partitions of their own, reading operand ``a``
    pointwise and operand ``b`` through an all-or-nothing broadcast
    frontier (the Appendix-A ``S`` collapses to wait-for-last-write).

Run:  PYTHONPATH=src python examples/transformer_pipeline.py
"""

import numpy as np

from repro.core import (Simulator, build_tiny_transformer, compile_model,
                        execute_reference, make_chip)


def main():
    # 1. a single-head encoder block + classifier head over 4 tokens
    graph = build_tiny_transformer(seq=4, d_model=8, d_head=8, d_ff=16)
    n_xbar = sum(1 for n in graph.nodes if n.op in ("conv2d", "gemm"))
    n_dyn = sum(1 for n in graph.nodes if n.op == "matmul")
    print(f"graph: {len(graph.nodes)} nodes — {n_xbar} crossbar ops "
          f"(projections/MLP/head), {n_dyn} dynamic matmuls (attention)")

    # 2. compile onto a 12-core banded chip: one partition per crossbar op,
    #    plus crossbar-less DPU partitions for QKᵀ/attn·V
    chip = make_chip(12, "banded")
    prog = compile_model(graph, chip)
    for cid in sorted(prog.cores):
        cfg = prog.cores[cid]
        kind = (f"xbar {cfg.xbar_node.name}" if cfg.xbar_node is not None
                else "DPU " + "/".join(n.op for n in cfg.dpu_nodes))
        print(f"  core {cid}: {kind}")

    # 3. simulate a token stream, pipelined, on both engines
    rng = np.random.default_rng(0)
    images = [rng.normal(size=(8, 4, 1)).astype(np.float32)
              for _ in range(4)]
    sim = Simulator(prog, chip, check_raw=True)
    outs, stats = sim.run(images, schedule="pipelined")
    _, seq = sim.run(images, schedule="sequential")
    print(f"pipelined: {stats.cycles} cycles vs sequential {seq.cycles} "
          f"({seq.cycles / stats.cycles:.2f}x)")

    # 4. verify against the pure-numpy graph oracle
    for img, out in zip(images, outs):
        want = execute_reference(graph, {"x": img})
        for v in want:
            np.testing.assert_allclose(out[v], want[v], rtol=1e-5, atol=1e-5)
    print("outputs match the reference executor")

    # 5. scale out: the same graph across a 2-chip mesh — cut edges become
    #    inter-chip DMA streams, outputs stay bitwise identical
    small = make_chip(6, "banded")
    prog2 = compile_model(graph, small, chips=2)
    outs2, stats2 = Simulator(prog2, small, check_raw=True).run(images)
    for a, b in zip(outs, outs2):
        for v in a:
            np.testing.assert_array_equal(a[v], b[v])
    link_load = {k: f"{ls.busy / stats2.cycles:.2f}"
                 for k, ls in stats2.links.items()}
    print(f"2-chip mesh: {stats2.cycles} cycles, link occupancy {link_load}, "
          f"outputs bitwise equal to 1 chip")


if __name__ == "__main__":
    main()
