"""Example: elastic restart after losing devices mid-training.

Simulates the 1000-node failure story at laptop scale (8 forced host
devices): train on a (4 data, 2 model) mesh, checkpoint, "lose" half the
fleet, re-plan the mesh with repro.distributed.plan_mesh, and resume on
(2, 2) from the same sharding-agnostic checkpoint — loss curve continues.

Run: PYTHONPATH=src python examples/elastic_restart.py
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile  # noqa: E402

from repro.configs.base import smoke_config  # noqa: E402
from repro.distributed import plan_mesh  # noqa: E402
from repro.train.loop import Trainer  # noqa: E402


def main():
    cfg = smoke_config("llama3.2-3b")
    with tempfile.TemporaryDirectory() as ckpt:
        # phase 1: full fleet (8 devices)
        plan = plan_mesh(8, cfg, prefer_model=2, pod_size=8)
        print(f"full fleet: mesh={plan.mesh_shape} axes={plan.axis_names} "
              f"idle={plan.n_idle}")
        trainer = Trainer(cfg, batch=8, seq_len=32, ckpt_dir=ckpt,
                          ckpt_every=5)
        trainer.run(10)
        loss_before = trainer.history[-1]

        # phase 2: 4 devices "fail" -> re-plan and resume from checkpoint
        degraded = plan_mesh(4, cfg, prefer_model=2, pod_size=8)
        print(f"degraded fleet: mesh={degraded.mesh_shape} "
              f"axes={degraded.axis_names} idle={degraded.n_idle}")
        trainer2 = Trainer(cfg, batch=8, seq_len=32, ckpt_dir=ckpt,
                           ckpt_every=5)
        state2 = trainer2.resume_or_init()
        print(f"resumed at step {int(state2.step)} "
              "(checkpointed during full-fleet phase)")
        trainer2.run(10, state=state2)
        loss_after = trainer2.history[-1]
        print(f"loss before failure: {loss_before:.4f}, "
              f"after elastic resume + 10 steps: {loss_after:.4f}")
        assert loss_after < loss_before * 1.5, "training diverged on resume"


if __name__ == "__main__":
    main()
