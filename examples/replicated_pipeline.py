"""Bottleneck-stage replication: pushing pipe_util toward 1.0.

lenet's conv1 runs 100 iterations while its downstream stages run 9 and 1,
so the pipeline idles behind one stage (pipe_util ~0.37 in
BENCH_pipeline.json).  This example replicates conv1 across k crossbars —
iteration ``i`` executes on replica ``i mod k`` and consumers merge the k
interleaved streams at their dependency frontier — shows utilization and
throughput-per-core climb with k, and verifies every output stays
**bitwise** the unreplicated program's.

Run:  PYTHONPATH=src python examples/replicated_pipeline.py
"""

import numpy as np

from repro.core import Simulator, build_lenet_like, compile_model, make_chip


def run(graph, chip, images, replicate=None):
    prog = compile_model(graph, chip, replicate=replicate,
                         validate=replicate is not None)
    out, st = Simulator(prog, chip).run(images)
    return out, st


def main():
    g = build_lenet_like()
    rng = np.random.default_rng(0)
    images = [rng.standard_normal((1, 12, 12)).astype(np.float32)
              for _ in range(8)]

    # 18 cores and a GCU streaming 16 px/cycle: enough of both that the
    # replicated conv1 is actually fed (at the default dma=4 the input
    # stream, not the crossbar count, caps the win around 0.55)
    chip = make_chip(18, "all_to_all", dma_pixels_per_cycle=16)
    base_out, sb = run(g, chip, images)
    tpc0 = len(images) / (sb.cycles * len(sb.busy))
    print(f"unreplicated  : {sb.cycles:4d} cycles, "
          f"pipe_util {sb.mean_utilization():.3f}, "
          f"{len(sb.busy):2d} busy cores")

    for plan in ({"conv1": 2}, {"conv1": 4}, "auto"):
        out, st = run(g, chip, images, replicate=plan)
        for a, b in zip(base_out, out):
            for v in a:
                np.testing.assert_array_equal(a[v], b[v])
        tpc = len(images) / (st.cycles * len(st.busy))
        print(f"{str(plan):<14}: {st.cycles:4d} cycles, "
              f"pipe_util {st.mean_utilization():.3f}, "
              f"{len(st.busy):2d} busy cores, "
              f"throughput/core x{tpc / tpc0:.2f} — outputs bitwise equal")


if __name__ == "__main__":
    main()
