"""End-to-end driver (deliverable b): train a ~100M-param llama-style model
for a few hundred steps with the full production stack — synthetic data
pipeline, AdamW + cosine schedule, async checkpointing, watchdog — and
verify the loss decreases.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import dataclasses
import tempfile
import time

from repro.configs.base import get_arch
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: llama3.2 family scaled to d=512 / 8 layers / 32k vocab
    cfg = dataclasses.replace(
        get_arch("llama3.2-3b"), name="llama-100m",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32768, param_dtype="float32",
        compute_dtype="float32", q_chunk=128, tie_embeddings=False)
    from repro.configs.base import register
    register(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr = Trainer(cfg=cfg, batch=args.batch, seq_len=args.seq_len,
                     ckpt_dir=ckpt_dir, ckpt_every=100, peak_lr=3e-3)
        t0 = time.monotonic()
        tr.run(args.steps)
        dt = time.monotonic() - t0
        tok_per_s = args.batch * args.seq_len * len(tr.history) / dt
        first = sum(tr.history[:10]) / 10
        last = sum(tr.history[-10:]) / 10
        print(f"{len(tr.history)} steps in {dt:.1f}s "
              f"({tok_per_s:,.0f} tok/s on this host)")
        print(f"loss: {first:.4f} -> {last:.4f}")
        assert last < first - 0.5, "loss did not decrease enough"
        print("loss decreased — OK")


if __name__ == "__main__":
    main()
